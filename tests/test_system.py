"""End-to-end system tests: the launcher step functions executed for real on
a 1x1 CPU mesh with reduced configs — train steps run, losses fall, serving
steps produce tokens, coupling paths agree numerically."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import use_mesh
from repro.configs import ShapeCell, get_arch
from repro.core.aimc import AimcConfig, program_linear
from repro.core.coupling import loose_forward, tight_forward
from repro.data.pipeline import DataConfig, host_batch
from repro.launch.mesh import make_mesh
from repro.launch.shardings import to_named
from repro.launch.steps import make_step
from repro.models.layers import Execution


def _tiny_spec(arch_id: str, **overrides):
    """An ArchSpec whose FULL config is the smoke config (CPU-runnable)."""
    spec = get_arch(arch_id)
    return dataclasses.replace(spec, model_cfg=spec.smoke_cfg, **overrides)


def _run_train(arch_id, steps=3, exec_mode="digital"):
    spec = _tiny_spec(arch_id)
    cell = ShapeCell("tiny", seq_len=32, global_batch=4, kind="train")
    mesh = make_mesh((1, 1), ("data", "model"))
    exe = (Execution(mode="aimc", aimc=AimcConfig(tile_rows=128, impl="ref"))
           if exec_mode == "aimc" else Execution())
    with use_mesh(mesh):
        bundle = make_step(spec, cell, mesh, exe)
        step = jax.jit(bundle.fn,
                       in_shardings=to_named(bundle.in_shardings, mesh),
                       out_shardings=to_named(bundle.out_shardings, mesh))
        model = spec.model_module()
        params = jax.tree.map(
            lambda x: x.astype(jnp.float32),
            model.init(jax.random.PRNGKey(0), spec.smoke_cfg))
        from repro.optim import make_optimizer
        opt_state = make_optimizer(spec.optimizer)[0](params)
        cfgd = DataConfig(vocab=spec.smoke_cfg.vocab, seq_len=cell.seq_len,
                          global_batch=cell.global_batch)
        losses = []
        for i in range(steps):
            hb = host_batch(cfgd, i, 0, 1)
            batch = {"tokens": jnp.asarray(hb["tokens"]),
                     "labels": jnp.asarray(hb["labels"])}
            if spec.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (cell.global_batch, spec.smoke_cfg.n_patches,
                     spec.smoke_cfg.d_model), jnp.bfloat16)
                batch["labels"] = batch["labels"].at[
                    :, :spec.smoke_cfg.n_patches].set(-1)
            rng = jnp.asarray([0, i], jnp.uint32)
            params, opt_state, metrics = step(params, opt_state, batch, rng)
            losses.append(float(metrics["loss"]))
        return losses


@pytest.mark.parametrize("arch_id", ["llama32_3b", "olmoe_1b_7b",
                                     "xlstm_350m"])
def test_train_step_runs_and_learns(arch_id):
    losses = _run_train(arch_id, steps=4)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], f"loss did not fall: {losses}"


def test_train_step_aimc_mode():
    """The paper's technique inside the full training loop (noise-aware)."""
    losses = _run_train("llama32_3b", steps=3, exec_mode="aimc")
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] * 1.05


def test_serve_steps_run():
    spec = _tiny_spec("granite_8b")
    cell = ShapeCell("tiny_dec", seq_len=64, global_batch=2, kind="decode")
    mesh = make_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        bundle = make_step(spec, cell, mesh, Execution())
        step = jax.jit(bundle.fn,
                       in_shardings=to_named(bundle.in_shardings, mesh),
                       out_shardings=to_named(bundle.out_shardings, mesh))
        model = spec.model_module()
        params = model.init(jax.random.PRNGKey(0), spec.smoke_cfg)
        params = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
        cache = model.init_cache(spec.smoke_cfg, 2, 64, jnp.bfloat16)
        toks = jnp.ones((2, 1), jnp.int32)
        for _ in range(3):
            toks, cache = step(params, cache, toks)
        assert toks.shape == (2, 1)
        assert int(cache["len"][0]) == 3


def test_coupling_numerically_identical():
    """Tight (fused) and loose (HBM-staged) produce the same numbers —
    the coupling choice is a performance distinction, not a math one."""
    cfg = AimcConfig(tile_rows=256, impl="ref")
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 128)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 512))
    st = program_linear(w, cfg)
    y_t = tight_forward(st, x, cfg)
    y_l = loose_forward(st, x, cfg)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_l),
                               rtol=0, atol=1e-5)


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint on one mesh, restore onto another (elastic rescale)."""
    from repro.checkpoint import checkpoint
    from repro.launch.shardings import get_param_specs, fit_specs
    params = {"blocks": {"wq": jnp.arange(64.0).reshape(1, 8, 8)},
              "embed": jnp.ones((16, 8))}
    checkpoint.save(str(tmp_path), 5, params)
    mesh2 = make_mesh((1, 1), ("data", "model"))  # CPU: same shape, new mesh
    specs = fit_specs(get_param_specs(params, mesh2), params, mesh2)
    step, restored, _ = checkpoint.restore_latest(str(tmp_path), params,
                                                  mesh2, specs)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["blocks"]["wq"]),
                                  np.asarray(params["blocks"]["wq"]))
