"""Drift, health, and chaos contract suite (DESIGN.md §14).

The ISSUE-8 acceptance criteria pinned here:
  * time-dependent degradation: the power-law `drift_gain_at` law, the
    per-core nu variation hooks, and the program-age clock
    (`AimcProgram.t_programmed` / `reprogrammed`) are deterministic and
    restart correctly on reprogramming;
  * capped-exponential backoff with DETERMINISTIC jitter: the schedule is
    pinned by value, `resilient_step` sleeps exactly it (injected sleep);
  * the straggler monitor exempts flagged recalibration windows from the
    EWMA — recovery never trips the straggler callback and never poisons
    the baseline;
  * hot reprogramming is BIT-EXACT: `Recalibrator.fresh_state` reproduces
    the original program state bit-for-bit under the original key;
  * dead-core drain (`remap_context`) never overlaps tiles and leaves the
    shape-only CM_* books invariant;
  * mid-trace recovery: a core killed at a chunk boundary drops ZERO
    in-flight requests, the CM_* ledgers (including the extra
    CM_INITIALIZE of the hot reprogram) reconcile exactly, and the
    remapped run's output is BIT-EQUAL to an unfaulted run.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core import noise as noise_lib
from repro.core.aimc import AimcConfig
from repro.core.program import (CapacityError, MappingPlan,
                                installed_entries, program_model)
from repro.core.schedule import CoreSchedule
from repro.core.tile import overlapping_placements
from repro.models.layers import Execution
from repro.runtime.batcher import poisson_trace, reconcile
from repro.runtime.chaos import FaultEvent, FaultInjector, parse_chaos
from repro.runtime.engine import ServeEngine
from repro.runtime.fault_tolerance import (StragglerMonitor, backoff_schedule,
                                           resilient_step)
from repro.runtime.health import build_health, reconcile_recal

EXE = Execution(compute_dtype="float32")


# ---------------------------------------------------------------------------
# drift model (core/noise.py)
# ---------------------------------------------------------------------------

def test_drift_gain_power_law():
    nm = noise_lib.drift_only(nu=0.1, t0=1.0)
    # G(t)/G(t0) = (t/t0)^-nu once t > t0
    assert nm.drift_gain_at(10.0) == pytest.approx(10.0 ** -0.1)
    assert nm.drift_gain_at(100.0) == pytest.approx(100.0 ** -0.1)
    # before the reference time there is no decay
    assert nm.drift_gain_at(0.5) == 1.0
    assert nm.drift_gain_at(0.0) == 1.0
    # explicit nu override (the per-core path)
    assert nm.drift_gain_at(10.0, nu=0.2) == pytest.approx(10.0 ** -0.2)
    # disabled model / zero exponent: no drift
    assert noise_lib.NoiseModel(enabled=False).drift_gain_at(1e6) == 1.0
    assert noise_lib.drift_only(nu=0.0).drift_gain_at(1e6) == 1.0


def test_per_core_nu_variation_deterministic():
    nm = noise_lib.drift_only(nu=0.1, core_spread=0.2)
    nus = [nm.per_core_nu(c) for c in range(8)]
    # bounded: nu * (1 +- spread)
    assert all(0.08 <= v <= 0.12 for v in nus)
    # cores differ, repeats agree (hash, not RNG state)
    assert len(set(nus)) > 1
    assert nus == [nm.per_core_nu(c) for c in range(8)]
    # no spread -> exact nu everywhere
    flat = noise_lib.drift_only(nu=0.1)
    assert all(flat.per_core_nu(c) == 0.1 for c in range(4))


# ---------------------------------------------------------------------------
# backoff (fault_tolerance.py)
# ---------------------------------------------------------------------------

def test_backoff_schedule_pinned():
    # the exact schedule (seed 0): capped exponential with splitmix jitter
    sched = backoff_schedule(4, base=0.05, cap=0.4, jitter=0.5, seed=0)
    assert sched == pytest.approx(
        (0.0388116791, 0.0864757143, 0.2067804045, 0.3341647346), rel=1e-8)
    # deterministic across calls; different seed, different jitter
    assert sched == backoff_schedule(4, base=0.05, cap=0.4, jitter=0.5,
                                     seed=0)
    assert sched != backoff_schedule(4, base=0.05, cap=0.4, jitter=0.5,
                                     seed=1)
    # jitter=0 is the pure capped exponential
    assert backoff_schedule(4, base=0.05, cap=0.4, jitter=0.0) == \
        (0.05, 0.1, 0.2, 0.4)
    # the cap bounds every jittered delay: delay <= cap * (1 + jitter)
    long = backoff_schedule(20, base=0.05, cap=0.4, jitter=0.5, seed=3)
    assert all(d <= 0.4 * 1.5 for d in long)


def test_resilient_step_sleeps_the_pinned_schedule():
    slept = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 4:
            raise OSError("connection reset")
        return "ok"

    fn = resilient_step(flaky, max_retries=3, base_delay=0.05,
                        max_delay=0.4, jitter=0.5, seed=0,
                        sleep=slept.append)
    assert fn() == "ok"
    assert tuple(slept) == pytest.approx(
        backoff_schedule(3, base=0.05, cap=0.4, jitter=0.5, seed=0))


def test_resilient_step_terminal_error_does_not_sleep():
    slept = []

    def oom():
        raise RuntimeError("RESOURCE_EXHAUSTED: out of memory")

    fn = resilient_step(oom, max_retries=3, sleep=slept.append)
    with pytest.raises(RuntimeError):
        fn()
    assert slept == []


# ---------------------------------------------------------------------------
# straggler exemption (fault_tolerance.py)
# ---------------------------------------------------------------------------

def test_straggler_monitor_exempts_recal_windows():
    flagged = []
    mon = StragglerMonitor(threshold=2.0, warmup=3,
                           on_straggler=lambda *a: flagged.append(a))
    for i in range(3):
        mon.record(i, 0.1)
    ewma0 = mon.ewma
    # a recal chunk is 100x slower — exempt: not flagged, EWMA untouched
    assert mon.record(3, 10.0, exempt=True) is False
    assert flagged == []
    assert mon.ewma == ewma0
    assert mon.exempted == [(3, 10.0)]
    # the same sample NOT exempted is flagged
    assert mon.record(4, 10.0) is True
    assert len(flagged) == 1
    # exempt samples during warmup never enter the seed buffer
    mon2 = StragglerMonitor(threshold=2.0, warmup=2)
    mon2.record(0, 5.0, exempt=True)
    mon2.record(1, 0.1)
    mon2.record(2, 0.1)
    assert mon2.ewma == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# chaos spec parsing (runtime/chaos.py)
# ---------------------------------------------------------------------------

def test_parse_chaos_specs():
    inj = parse_chaos("corrupt:0@2:0.5,kill:1@4")
    assert [e.describe() for e in inj.events] == [
        "corrupt core 0 @ chunk 2 (magnitude 0.5)",
        "kill core 1 @ chunk 4"]
    # events fire one-shot, in chunk order, once the counter passes them
    assert inj.due(1) == []
    assert [e.kind for e in inj.due(4)] == ["corrupt", "kill"]
    assert inj.due(9) == []
    assert inj.exhausted and len(inj.fired) == 2
    for bad in ("", "explode:0@1", "kill:0", "corrupt:0@1:1.5"):
        with pytest.raises(ValueError):
            parse_chaos(bad)


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(at_chunk=0, kind="meteor", core=0)
    with pytest.raises(ValueError):
        FaultEvent(at_chunk=0, kind="corrupt", core=0, magnitude=0.0)
    # out-of-order schedules sort by chunk
    inj = FaultInjector([FaultEvent(5, "kill", 1), FaultEvent(2, "kill", 0)])
    assert [e.at_chunk for e in inj.events] == [2, 5]


# ---------------------------------------------------------------------------
# program-age clock + drain/repair (core/program.py) and health
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tfm():
    spec = get_arch("granite-8b")
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    return spec, cfg, model, params


@pytest.fixture(scope="module")
def programmed(tfm):
    """(program, plan, key, params_raw, aimc) on 2 virtual cores."""
    spec, cfg, model, params = tfm
    aimc = AimcConfig(impl="ref", input_scale=0.1)
    plan = MappingPlan(n_contexts=2)
    key = jax.random.PRNGKey(3)
    program = program_model(params, plan, aimc, key)
    return program, plan, key, params, aimc


def test_program_age_clock(programmed):
    program, _, _, _, _ = programmed
    assert program.t_programmed == (0.0,) * len(program.names)
    assert program.ages(5.0) == {n: 5.0 for n in program.names}
    # reprogramming ONE matrix restarts only that matrix's clock
    name = program.names[0]
    prog2 = program.reprogrammed({name: program.states[0]}, 5.0)
    assert prog2.t_programmed[0] == 5.0
    assert prog2.t_programmed[1:] == program.t_programmed[1:]
    assert prog2.ages(7.0)[name] == 2.0
    with pytest.raises(KeyError):
        program.reprogrammed({"nope": program.states[0]}, 1.0)


def test_drift_gains_and_aged_entries(programmed):
    program, _, _, _, _ = programmed
    nm = noise_lib.drift_only(nu=0.1, t0=1.0)
    gains = program.drift_gains(10.0, nm)
    assert set(gains) == set(program.names)
    assert all(g == pytest.approx(10.0 ** -0.1) for g in gains.values())
    # aged entries scale s_w by exactly the gain; codes untouched
    entries = program.aged_entries(10.0, nm)
    st0, aged0 = program.states[0], entries[program.names[0]]
    assert jnp.array_equal(aged0.w_q, st0.w_q)
    assert jnp.allclose(aged0.s_w, st0.s_w * (10.0 ** -0.1))
    # inside t0 nothing ages -> no entries at all
    assert program.aged_entries(0.5, nm) == {}


def test_install_updates_swaps_only_named_states(programmed, tfm):
    program, _, _, params, _ = programmed
    installed = program.install(params)
    name = program.names[0]
    aged = program.states[0].with_gain(0.5)
    updated = program.install_updates(installed, {name: aged})
    live = installed_entries(updated)
    assert jnp.allclose(live[name].s_w, program.states[0].s_w * 0.5)
    other = program.names[1]
    assert jnp.array_equal(live[other].s_w,
                           installed_entries(installed)[other].s_w)
    with pytest.raises(KeyError):
        program.install_updates(installed, {"nope": aged})


def test_remap_context_drains_without_overlap(programmed):
    program, _, _, _, _ = programmed
    dead = 1
    moved = [n for n, c in zip(program.names, program.contexts) if c == dead]
    assert moved, "fixture must place something on core 1"
    prog2 = program.remap_context(dead)
    # every matrix survives, none on the dead core, books are invariant
    assert prog2.names == program.names
    assert dead not in prog2.contexts
    assert prog2.mvm_counts() == program.mvm_counts()
    assert prog2.initialize_counts() == program.initialize_counts()
    # the re-packed placements never overlap resident tiles
    for ctx, tm in enumerate(prog2.tile_maps):
        assert overlapping_placements(tm.placements) == [], ctx
    with pytest.raises(ValueError):
        program.remap_context(99)


def test_remap_single_context_has_nowhere_to_drain(tfm):
    spec, cfg, model, params = tfm
    single = program_model(params, MappingPlan(),
                           AimcConfig(impl="ref", input_scale=0.1),
                           jax.random.PRNGKey(3))
    with pytest.raises(CapacityError):
        single.remap_context(0)


def test_reprogram_counts_match_initialize(programmed):
    program, _, _, _, _ = programmed
    # reprogramming EVERY matrix costs exactly the session's program bill
    assert (program.reprogram_counts(program.names).initialize
            == program.initialize_counts().initialize)
    some = program.names[:2]
    assert (program.reprogram_counts(some).initialize
            < program.initialize_counts().initialize)


def test_mesh_placement_folds_over_survivors():
    class _Mesh:
        axis_names = ("model",)
        shape = {"model": 3}

    spec = get_arch("granite-8b")
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), spec.smoke_cfg)
    program = program_model(params, MappingPlan(n_contexts=2),
                            AimcConfig(impl="ref", input_scale=0.1),
                            jax.random.PRNGKey(3))
    sched = CoreSchedule.from_program(program)
    assert sched.mesh_placement(_Mesh()) == {0: 0, 1: 1}
    # device 0 lost: cores fold round-robin over the survivors
    assert sched.mesh_placement(_Mesh(), dead=(0,)) == {0: 1, 1: 2}
    assert sched.mesh_placement(_Mesh(), dead=(0, 2)) == {0: 1, 1: 1}
    with pytest.raises(ValueError):
        sched.mesh_placement(_Mesh(), dead=(0, 1, 2))


# ---------------------------------------------------------------------------
# health monitor + bit-exact recalibration (runtime/health.py)
# ---------------------------------------------------------------------------

def test_recalibrator_reprogram_is_bit_exact(programmed):
    program, plan, key, params_raw, _ = programmed
    health = build_health(program, params_raw, plan, key)
    for name, st in zip(program.names, program.states):
        fresh = health.recal.fresh_state(name)
        assert jnp.array_equal(fresh.w_q, st.w_q), name
        assert jnp.array_equal(fresh.s_w, st.s_w), name


def test_health_probe_fresh_drifted_corrupted(programmed):
    program, plan, key, params_raw, _ = programmed
    health = build_health(program, params_raw, plan, key)
    fresh = dict(zip(program.names, program.states))
    # fresh states ARE the oracle reference: error identically 0
    s0 = health.probe(fresh, 0.0)
    assert set(s0.errors) == set(program.contexts)
    assert all(e == 0.0 for e in s0.errors.values())
    assert health.failing_cores(s0) == ()
    # a pure gain g reads back as relative error exactly 1-g
    g = 0.9
    s1 = health.probe({n: st.with_gain(g) for n, st in fresh.items()}, 1.0)
    assert all(e == pytest.approx(1.0 - g, abs=1e-5)
               for e in s1.errors.values())
    assert health.failing_cores(s1) == tuple(sorted(set(program.contexts)))
    # a dead crossbar reads as error 1.0 on ITS core only
    from repro.runtime.chaos import corrupt_entries
    s2 = health.probe({**fresh, **corrupt_entries(program, 1, 1.0)}, 2.0)
    assert s2.errors[1] == pytest.approx(1.0)
    assert s2.errors[0] == 0.0
    assert health.failing_cores(s2) == (1,)


def test_recalibrate_dead_core_drains_and_bills(programmed):
    program, plan, key, params_raw, _ = programmed
    health = build_health(program, params_raw, plan, key)
    health.mark_dead(1)
    entries, names, cm = health.recalibrate({1}, t_now=3.0)
    assert set(names) == {n for n, c in zip(program.names, program.contexts)
                          if c == 1}
    assert cm.initialize == program.reprogram_counts(names).initialize > 0
    # the repaired program has drained core 1 and restamped the clocks
    prog2 = health.program
    assert 1 not in prog2.contexts
    for n, t in zip(prog2.names, prog2.t_programmed):
        assert t == (3.0 if n in names else 0.0), n
    assert health.dead == set()
    # repaired states are bit-equal to the original program (same keys)
    for n in names:
        i = program.names.index(n)
        assert jnp.array_equal(entries[n].w_q, program.states[i].w_q)
        assert jnp.array_equal(entries[n].s_w, program.states[i].s_w)


# ---------------------------------------------------------------------------
# mid-trace recovery through the engine (the tentpole contract)
# ---------------------------------------------------------------------------

def _make_engine(tfm, program, params, **kw):
    spec, cfg, model, _ = tfm
    aimc = program.cfg
    exe = Execution(mode="aimc", aimc=aimc, compute_dtype="float32",
                    programmed=True)
    sched = CoreSchedule.from_program(program)
    kw.setdefault("n_slots", 3)
    kw.setdefault("prompt_pad", 8)
    kw.setdefault("max_seq", 24)
    kw.setdefault("decode_chunk", 4)
    return ServeEngine(model, cfg, exe, program.install(params),
                       family=spec.family, module=spec.module,
                       program=program, schedule=sched, **kw)


def test_mid_trace_kill_recovers_bit_equal_with_exact_books(tfm, programmed):
    program, plan, key, params_raw, _ = programmed
    reqs = poisson_trace(8, rate=300.0, seed=9, prompt_len=(3, 8),
                         max_new=(1, 9), vocab=tfm[1].vocab)
    # the unfaulted oracle
    ref_eng = _make_engine(tfm, program, params_raw)
    ref_eng.warmup()
    ref = ref_eng.serve(list(reqs))

    health = build_health(program, params_raw, plan, key)
    chaos = parse_chaos("kill:1@2")
    eng = _make_engine(tfm, program, params_raw, health=health, chaos=chaos)
    eng.warmup()
    rep = eng.serve(list(reqs))

    # (a) the fault fired mid-trace and NO in-flight request was dropped
    assert chaos.exhausted
    assert [e.describe() for e in rep.fault_events] == ["kill core 1 @ chunk 2"]
    assert set(rep.records) == {r.rid for r in reqs}
    assert all(rec.finish_reason in ("eos", "length")
               for rec in rep.records.values())
    # (b) recovery happened: core 1 drained onto a peer, states reprogrammed
    assert rep.n_recals >= 1
    assert rep.recal_events[0].reason == "dead_core"
    assert 1 not in eng.program.contexts
    assert eng.health.dead == set()
    # the engine's schedule follows the remapped program
    assert set(s.core for s in eng.schedule.shards) == {0}
    # (c) CM_* books reconcile EXACTLY against the recovered program,
    # including the extra CM_INITIALIZE of the hot reprogram
    led_sum, static_sum = reconcile(eng.program, rep.records,
                                    rep.observed_vectors)
    assert led_sum == static_sum
    assert rep.recal_initialize == \
        program.reprogram_counts(rep.recal_events[0].names).initialize > 0
    assert reconcile_recal(eng.program, rep)
    # (d) recovery is invisible in the tokens: bit-equal to the unfaulted run
    for r in reqs:
        assert rep.tokens(r.rid) == ref.tokens(r.rid), r.rid
        assert (rep.records[r.rid].finish_reason
                == ref.records[r.rid].finish_reason), r.rid
    # (e) the recal chunk was exempted from the straggler EWMA
    assert len(eng.monitor.exempted) >= 1
    assert eng.monitor.flagged == []


def test_mid_trace_corruption_repaired_in_place(tfm, programmed):
    program, plan, key, params_raw, _ = programmed
    reqs = poisson_trace(6, rate=300.0, seed=4, prompt_len=(3, 8),
                         max_new=(2, 8), vocab=tfm[1].vocab)
    ref_eng = _make_engine(tfm, program, params_raw)
    ref_eng.warmup()
    ref = ref_eng.serve(list(reqs))

    health = build_health(program, params_raw, plan, key)
    chaos = parse_chaos("corrupt:0@1:0.5")
    eng = _make_engine(tfm, program, params_raw, health=health, chaos=chaos)
    eng.warmup()
    rep = eng.serve(list(reqs))
    assert chaos.exhausted and rep.n_recals >= 1
    assert rep.recal_events[0].reason == "fault"
    # corruption is repaired IN PLACE: no remap, contexts unchanged
    assert eng.program.contexts == program.contexts
    assert set(rep.records) == {r.rid for r in reqs}
    for r in reqs:
        assert rep.tokens(r.rid) == ref.tokens(r.rid), r.rid
    assert reconcile_recal(eng.program, rep)


def test_engine_heartbeat_beats_per_chunk(tfm, programmed, tmp_path):
    from repro.runtime.fault_tolerance import Heartbeat
    program, plan, key, params_raw, _ = programmed
    hb = Heartbeat(str(tmp_path / "hb.json"))
    eng = _make_engine(tfm, program, params_raw, heartbeat=hb)
    eng.warmup()
    reqs = poisson_trace(4, rate=300.0, seed=2, prompt_len=(3, 8),
                         max_new=(2, 6), vocab=tfm[1].vocab)
    rep = eng.serve(list(reqs))
    beat = hb.read()
    assert beat is not None and rep.n_steps > 0
    # slot occupancy + last-chunk wall timestamp, as the supervisor sees it
    for field in ("step", "time", "slots_busy", "slots_free", "chunk_len",
                  "last_chunk_s", "wall_decode_s", "n_recals"):
        assert field in beat, field
    assert beat["slots_busy"] + beat["slots_free"] == eng.n_slots
    assert beat["n_recals"] == 0


def test_engine_validates_health_and_chaos_wiring(tfm, programmed):
    program, plan, key, params_raw, _ = programmed
    health = build_health(program, params_raw, plan, key)
    with pytest.raises(ValueError, match="requires an AimcProgram"):
        spec, cfg, model, params = tfm
        ServeEngine(model, cfg, EXE, params, family=spec.family,
                    module=spec.module, health=health)
    with pytest.raises(ValueError, match="requires a HealthMonitor"):
        _make_engine(tfm, program, params_raw,
                     chaos=parse_chaos("kill:1@2"))


# ---------------------------------------------------------------------------
# drift compensation folded into the dequant scale (ISSUE 10 satellite)
# ---------------------------------------------------------------------------

def test_compensation_gain_inverts_the_power_law():
    nm = noise_lib.drift_only(nu=0.1, t0=1.0, compensate=True)
    # between recals the dequant correction is exactly 1/G(t)
    assert nm.compensation_gain_at(10.0) == pytest.approx(10.0 ** 0.1)
    assert nm.compensation_gain_at(10.0) * nm.drift_gain_at(10.0) \
        == pytest.approx(1.0)
    # inside the reference window nothing has decayed -> no correction
    assert nm.compensation_gain_at(0.5) == 1.0
    # compensation off (the pre-fix static-per-program behavior) or model
    # disabled: the hook is inert
    assert noise_lib.drift_only(nu=0.1).compensation_gain_at(10.0) == 1.0
    assert noise_lib.NoiseModel(enabled=False,
                                drift_compensate=True
                                ).compensation_gain_at(1e6) == 1.0


def test_drift_compensation_collapses_probe_error(programmed):
    """Before/after pin of the satellite fix: with zero core spread the
    age-based dequant correction cancels the decay EXACTLY, so the probe
    error collapses from ~(1 - G(t)) to ~0 between recals."""
    program, plan, key, params_raw, _ = programmed
    t = 100.0
    raw = build_health(program, params_raw, plan, key,
                       noise=noise_lib.drift_only(nu=0.1, t0=1.0))
    comp = build_health(program, params_raw, plan, key,
                        noise=noise_lib.drift_only(nu=0.1, t0=1.0,
                                                   compensate=True))
    fresh = dict(zip(program.names, program.states))
    # uncompensated: a pure gain g reads back as error exactly 1 - g
    g = 100.0 ** -0.1
    s_raw = raw.probe({**fresh, **raw.drifted_entries(t)}, t)
    assert all(e == pytest.approx(1.0 - g, abs=1e-5)
               for e in s_raw.errors.values())
    assert raw.failing_cores(s_raw) != ()
    # compensated: decay x correction cancels, no core trips the probe
    s_comp = comp.probe({**fresh, **comp.drifted_entries(t)}, t)
    assert all(e == pytest.approx(0.0, abs=1e-5)
               for e in s_comp.errors.values())
    assert comp.failing_cores(s_comp) == ()


def test_drift_compensation_with_core_spread_leaves_residual(programmed):
    """With per-core nu variation the compensator (which only knows the
    NOMINAL exponent) cannot cancel exactly: the error drops vs the raw
    decay but stays nonzero — recalibration still has a job."""
    program, plan, key, params_raw, _ = programmed
    t = 100.0
    raw = build_health(program, params_raw, plan, key,
                       noise=noise_lib.drift_only(nu=0.1, t0=1.0,
                                                  core_spread=0.5))
    comp = build_health(program, params_raw, plan, key,
                        noise=noise_lib.drift_only(nu=0.1, t0=1.0,
                                                   core_spread=0.5,
                                                   compensate=True))
    fresh = dict(zip(program.names, program.states))
    s_raw = raw.probe({**fresh, **raw.drifted_entries(t)}, t)
    s_comp = comp.probe({**fresh, **comp.drifted_entries(t)}, t)
    for core, e_raw in s_raw.errors.items():
        e_comp = s_comp.errors[core]
        assert e_comp < e_raw, (core, e_comp, e_raw)
        assert e_comp > 0.0, core
