"""Chunked (k-step scanned) decode equivalence suite (runtime/engine.py,
DESIGN.md §13).

The contracts pinned here (ISSUE 7 acceptance criteria):
  * decode is BIT-EQUAL across ``decode_chunk`` sizes (k in {1, 4, 8}) and
    against the legacy per-step loop's oracle (`static_generate`) — single
    device in-process, forced 2-device data/model meshes in a subprocess;
  * mid-chunk retirement works: an EOS inside a chunk frees the slot for
    the next admission, and the freed lane stays bit-frozen;
  * per-chunk ledger exactness: after EVERY `step()` the device-side
    observed vectors equal the per-request books and reconcile exactly
    against ``program.mvm_counts()`` — not just at end of trace;
  * EOS tokens are control, not payload: they never appear in delivered
    ``tokens`` but their decode vectors stay in the CM_* books, and an
    EOS-heavy trace still reconciles exactly;
  * the decode step performs NO host->device transfer (the active mask
    lives on device — the PR-7 fix for the per-step `jnp.asarray(active)`
    rebuild), enforced with a transfer guard.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.aimc import AimcConfig
from repro.core.program import MappingPlan, program_model
from repro.models.layers import Execution
from repro.runtime.batcher import (Request, poisson_trace, reconcile,
                                   synchronized_trace)
from repro.runtime.engine import ServeEngine, static_generate

EXE = Execution(compute_dtype="float32")


@pytest.fixture(scope="module")
def tfm():
    spec = get_arch("granite-8b")
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    return spec, cfg, model, params


def make_engine(tfm, **kw):
    spec, cfg, model, params = tfm
    kw.setdefault("n_slots", 3)
    kw.setdefault("prompt_pad", 8)
    kw.setdefault("max_seq", 24)
    kw.setdefault("family", spec.family)
    kw.setdefault("module", spec.module)
    return ServeEngine(model, cfg, kw.pop("exe", EXE),
                       kw.pop("params", params), **kw)


def _programmed(tfm, **kw):
    spec, cfg, model, params = tfm
    aimc = AimcConfig(impl="ref", input_scale=0.1)
    exe = Execution(mode="aimc", aimc=aimc, compute_dtype="float32",
                    programmed=True)
    program = program_model(params, MappingPlan(), aimc,
                            jax.random.PRNGKey(3))
    eng = make_engine(tfm, exe=exe, params=program.install(params),
                      program=program, **kw)
    return eng, program


# ---------------------------------------------------------------------------
# bit-equality across chunk sizes
# ---------------------------------------------------------------------------

def test_chunked_decode_bit_equal_across_k_on_ragged_trace(tfm):
    spec, cfg, model, params = tfm
    reqs = poisson_trace(8, rate=300.0, seed=9, prompt_len=(3, 8),
                         max_new=(1, 9), vocab=cfg.vocab)
    base = make_engine(tfm, n_slots=3, decode_chunk=1)
    base.warmup()
    ref = base.serve(list(reqs))
    for k in (4, 8):
        eng = make_engine(tfm, n_slots=3, decode_chunk=k)
        eng.warmup()
        rep = eng.serve(list(reqs))
        for r in reqs:
            assert rep.tokens(r.rid) == ref.tokens(r.rid), (k, r.rid)
            assert (rep.records[r.rid].finish_reason
                    == ref.records[r.rid].finish_reason), (k, r.rid)
            assert (rep.records[r.rid].decode_vectors
                    == ref.records[r.rid].decode_vectors), (k, r.rid)
        # chunking changes host scheduling, never the books
        assert rep.observed_vectors == rep.useful_vectors, k
        assert rep.generated_tokens == ref.generated_tokens, k
        # serving the ragged trace never recompiled anything: decode holds
        # exactly one executable per ladder length, all built at warmup
        assert eng.compile_counts() == {"prefill": 1, "insert": 1,
                                        "decode": len(eng._ladder)}, k


def test_chunked_sync_trace_bit_equal_static(tfm):
    spec, cfg, model, params = tfm
    reqs = synchronized_trace(3, prompt_len=8, max_new=6, seed=1,
                              vocab=cfg.vocab)
    prompts = jnp.asarray([r.prompt for r in reqs], jnp.int32)
    gen, _ = static_generate(model, cfg, EXE, params, prompts, 6, max_seq=24)
    for k in (4, 8):
        eng = make_engine(tfm, n_slots=3, decode_chunk=k)
        eng.warmup()
        report = eng.serve(list(reqs))
        for r in reqs:
            assert report.tokens(r.rid) == [int(t) for t in gen[r.rid]], \
                f"chunk {k}: req {r.rid} diverged from the static oracle"


# ---------------------------------------------------------------------------
# mid-chunk retirement frees the slot
# ---------------------------------------------------------------------------

def test_mid_chunk_eos_retirement_frees_slot_for_next_admit(tfm):
    base = make_engine(tfm, n_slots=1, decode_chunk=1)
    base.warmup()
    req = Request(rid=0, prompt=tuple(range(1, 9)), max_new=8)
    ref = base.serve([req]).tokens(0)
    assert len(ref) == 8
    eos = ref[2]          # emitted at decode step 2 — INSIDE a k=4 chunk
    eng = make_engine(tfm, n_slots=1, decode_chunk=4, eos_id=eos)
    eng.warmup()
    # two identical-prompt requests through ONE slot: the second can only
    # be served if the mid-chunk retirement released the lane
    reqs = [req, Request(rid=1, prompt=req.prompt, max_new=8)]
    report = eng.serve(reqs)
    assert len(report.records) == 2
    for rid in (0, 1):
        rec = report.records[rid]
        assert rec.finish_reason == "eos", rid
        assert rec.tokens == ref[:2], rid    # EOS excluded from payload
        assert rec.decode_vectors == 2, rid  # ... but in the vector books
    assert report.observed_vectors == report.useful_vectors


# ---------------------------------------------------------------------------
# per-chunk ledger exactness (session primitives, chunk boundaries)
# ---------------------------------------------------------------------------

def test_ledgers_exact_at_every_chunk_boundary(tfm):
    eng, program = _programmed(tfm, n_slots=2, decode_chunk=4, max_seq=20)
    eng.warmup()
    sess = eng.begin()
    now = 0.0
    reqs = poisson_trace(5, rate=1000.0, seed=4, prompt_len=(3, 8),
                         max_new=(2, 7), vocab=tfm[1].vocab)
    queue = list(reqs)
    per_vec = program.mvm_counts()
    chunks = 0
    while queue or sess.slots.n_busy:
        while sess.slots.n_free and queue:
            now = eng.admit(sess, queue.pop(0), now)
        if not sess.slots.n_busy:
            break
        now = eng.step(sess, now)
        chunks += 1
        # the books must close at EVERY chunk boundary, mid-flight records
        # included — not just after the trace drains
        rep = sess.report
        assert rep.observed_vectors == sum(
            r.vectors for r in rep.records.values()), chunks
        led_sum, static = reconcile(program, rep.records,
                                    rep.observed_vectors)
        assert led_sum == static, chunks
        assert static == per_vec.scaled(rep.observed_vectors), chunks
    report = eng.finish(sess, now)
    assert chunks >= 2                       # the loop actually chunked
    # <= k steps per chunk: the while_loop exits early once every lane
    # retires, and every executed step carries >= 1 busy lane
    assert chunks <= report.n_steps <= chunks * 4
    assert report.observed_vectors >= report.n_steps
    assert report.observed_vectors == report.useful_vectors


# ---------------------------------------------------------------------------
# EOS accounting (control, not payload) on an EOS-heavy trace
# ---------------------------------------------------------------------------

def test_eos_heavy_trace_reconciles_and_excludes_eos_payload(tfm):
    spec, cfg, model, params = tfm
    reqs = poisson_trace(8, rate=400.0, seed=11, prompt_len=(3, 8),
                         max_new=(2, 8), vocab=cfg.vocab)
    probe = make_engine(tfm, n_slots=3)
    probe.warmup()
    free_run = probe.serve(list(reqs))
    # pick the most frequent emitted token -> an EOS that fires a lot
    counts = {}
    for rec in free_run.records.values():
        for t in rec.tokens:
            counts[t] = counts.get(t, 0) + 1
    eos = max(counts, key=counts.get)
    eng, program = _programmed(tfm, n_slots=3, decode_chunk=4, eos_id=eos,
                               max_seq=24)
    eng.warmup()
    report = eng.serve(list(reqs))
    assert any(r.finish_reason == "eos" for r in report.records.values()), \
        "trace was not EOS-heavy; pick a different seed"
    for rid, rec in report.records.items():
        assert eos not in rec.tokens, rid    # EOS never delivered
        if rec.finish_reason == "eos":
            # the EOS ride is booked as a vector even though no token lands
            assert rec.decode_vectors == max(len(rec.tokens), 1), rid
    # both countings agree, and close exactly against the program
    assert report.observed_vectors == report.useful_vectors
    led_sum, static = reconcile(program, report.records,
                                report.observed_vectors)
    assert led_sum == static


# ---------------------------------------------------------------------------
# no per-step host->device transfer (the mask lives on device)
# ---------------------------------------------------------------------------

def test_decode_step_performs_no_host_to_device_transfer(tfm):
    eng = make_engine(tfm, n_slots=2, decode_chunk=2)
    eng.warmup()
    sess = eng.begin()
    now = eng.admit(sess, Request(rid=0, prompt=(1, 2, 3), max_new=9), now=0.0)
    now = eng.step(sess, now)    # post-warmup steady state
    # the PR-4 loop rebuilt the active mask with jnp.asarray(list) every
    # step — an h2d transfer per token. The chunked loop keeps the mask in
    # device state, so a steady-state step must not transfer ANYTHING to
    # the device (readback of ys is d2h and stays allowed).
    with jax.transfer_guard_host_to_device("disallow"):
        now = eng.step(sess, now)
        now = eng.step(sess, now)
    eng.cancel_active(sess, now)
    eng.finish(sess, now)


# ---------------------------------------------------------------------------
# forced 2-device meshes: chunked decode bit-equal to single-device
# (subprocess — XLA's device count is fixed at backend init)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chunked_sharded_bit_equal_across_two_devices():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=2 "
            + os.environ.get("XLA_FLAGS", ""))
        import jax, jax.numpy as jnp
        assert jax.device_count() == 2, jax.devices()
        from repro.configs import get_arch
        from repro.core.aimc import AimcConfig
        from repro.core.program import MappingPlan, program_model
        from repro.launch.mesh import make_mesh
        from repro.models.layers import Execution
        from repro.runtime.batcher import reconcile, synchronized_trace
        from repro.runtime.engine import ServeEngine, ShardedServeEngine

        spec = get_arch("granite-8b"); cfg = spec.smoke_cfg
        model = spec.model_module()
        params = model.init(jax.random.PRNGKey(0), cfg)
        aimc = AimcConfig(impl="ref", input_scale=0.1)
        exe = Execution(mode="aimc", aimc=aimc, compute_dtype="float32",
                        programmed=True)
        prog = program_model(params, MappingPlan(n_contexts=2), aimc,
                             jax.random.PRNGKey(2))
        params = prog.install(params)
        kw = dict(n_slots=2, prompt_pad=8, max_seq=20, family=spec.family,
                  module=spec.module, cache_dtype=jnp.float32, program=prog)
        reqs = synchronized_trace(4, prompt_len=8, max_new=6, seed=1,
                                  vocab=cfg.vocab)
        e1 = ServeEngine(model, cfg, exe, params, **kw); e1.warmup()
        ref = e1.serve(list(reqs))
        for shape in ((2, 1), (1, 2)):       # slots/data, bit lines/model
            mesh = make_mesh(shape, ("data", "model"))
            for k, n_exec in ((1, 1), (4, 3), (8, 4)):
                e2 = ShardedServeEngine(model, cfg, exe, params, mesh=mesh,
                                        decode_chunk=k, **kw)
                assert e2.warmup() == {"prefill": 1, "insert": 1,
                                       "decode": n_exec}, (shape, k)
                r2 = e2.serve(list(reqs))
                for r in reqs:
                    assert r2.tokens(r.rid) == ref.tokens(r.rid), (
                        shape, k, r.rid)
                assert e2.compile_counts() == {"prefill": 1, "insert": 1,
                                               "decode": n_exec}, (shape, k)
                assert r2.observed_vectors == r2.useful_vectors, (shape, k)
                ls, st = reconcile(prog, r2.records, r2.observed_vectors)
                assert ls == st, (shape, k)
        print("CHUNKED_SHARDED_BITEQUAL_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        ["src", env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "CHUNKED_SHARDED_BITEQUAL_OK" in proc.stdout
