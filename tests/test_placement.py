"""Auto-placement contract suite (core/placement.py, DESIGN.md §16).

The ISSUE-10 acceptance criteria pinned here:
  * the placer's per-layer packing oracle (`tile.pack_contexts`) agrees
    EXACTLY with the real `ProgramBuilder` packing — same total tiles,
    and the predicted max-per-context is the exact feasibility frontier
    (budget == packmax programs; budget == packmax-1 raises
    `CapacityError`);
  * a returned plan NEVER exceeds the tile budget — per rotation state
    when the model overflows, for the chosen analog set when it fits;
  * more budget never worsens predicted latency (monotone), and the
    chosen split is never worse than all-digital or than the densest
    all-analog prefix that fits;
  * capacity overflow degrades to a time-multiplexed `RotationPlan`
    whose states partition the analog set (nothing silently dropped);
  * the rotating engine serves BIT-EQUAL to the digital static oracle
    while billing one CM_INITIALIZE batch per swap — reconciled per
    event against `AimcProgram.reprogram_counts` — without a single
    post-warmup recompile;
  * per-request CM_* ledgers are refused under rotation (they are
    ill-defined: a request's vectors span states), as are the engine
    combinations that would break bit-stability (prefix cache, chunked
    prefill, health/chaos, sharding, multi-tenant serving).
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.aimc import AimcConfig
from repro.core.placement import (PlacementRoofline, RotationPlan,
                                  layer_costs, plan_placement,
                                  reconcile_swaps)
from repro.core.program import CapacityError, MappingPlan, program_model
from repro.core.tile import pack_contexts
from repro.models.layers import Execution
from repro.runtime.batcher import synchronized_trace
from repro.runtime.engine import ServeEngine, static_generate

# the LOCKED placement smoke config (ci.sh --fast serves the same one):
# small tiles force the smoke model to overflow a 2-tile budget, and the
# aimc output at this precision is token-equal to digital on this trace
ACFG = AimcConfig(impl="ref", adc_alpha=0.5, tile_rows=64)
SEED = 89


@pytest.fixture(scope="module")
def tfm():
    spec = get_arch("granite-8b")
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(SEED), cfg)
    return spec, cfg, model, params


@pytest.fixture(scope="module")
def placed(tfm):
    """Uncapped placement on the smoke model (every candidate fits)."""
    _, _, _, params = tfm
    return plan_placement(params, MappingPlan(), ACFG,
                          tiles_per_context=None, n_contexts=1)


def _packmax_of(res, resident, budgetless_cfg=ACFG):
    """Independent re-packing of ``resident`` via the public oracle."""
    items = [c.item for c in res.costs if c.path in set(resident)]
    per = pack_contexts(items, res.n_contexts, budgetless_cfg.tile_rows,
                        budgetless_cfg.tile_cols)
    return max(per) if per else 0


# ---------------------------------------------------------------------------
# cost enumeration + packing oracle vs the real program builder
# ---------------------------------------------------------------------------

def test_layer_costs_cover_every_mapped_leaf(tfm, placed):
    _, _, _, params = tfm
    prog = program_model(params, MappingPlan(), ACFG, jax.random.PRNGKey(1))
    assert {c.path for c in placed.costs} == set(prog.names)
    for c in placed.costs:
        assert c.t_digital > 0.0 and c.t_analog > 0.0
        assert c.tiles_alone >= 1 and c.instances >= 1
    # analog/digital is a partition of the cost set
    assert set(placed.analog) | set(placed.digital) == set(prog.names)
    assert not set(placed.analog) & set(placed.digital)


def test_pack_contexts_is_the_program_builders_packing(tfm, placed):
    _, _, _, params = tfm
    per = pack_contexts([c.item for c in placed.costs], 1,
                        ACFG.tile_rows, ACFG.tile_cols)
    prog = program_model(params, MappingPlan(), ACFG, jax.random.PRNGKey(1))
    assert prog.n_tiles == sum(per)
    # the predicted packmax is the exact capacity frontier of the builder
    packmax = max(per)
    ok = MappingPlan(tiles_per_context=packmax)
    program_model(params, ok, ACFG, jax.random.PRNGKey(1))   # must fit
    with pytest.raises(CapacityError):
        program_model(params, MappingPlan(tiles_per_context=packmax - 1),
                      ACFG, jax.random.PRNGKey(1))


def test_layer_costs_standalone_matches_plan_scope(tfm, placed):
    _, _, _, params = tfm
    costs = layer_costs(params, MappingPlan(), ACFG)
    assert [c.path for c in costs] == [c.path for c in placed.costs]
    assert [c.t_analog for c in costs] == [c.t_analog for c in placed.costs]


# ---------------------------------------------------------------------------
# budget law: cap honored, monotone, dominates the trivial splits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [1, 2, 3, 4, 8])
def test_budget_never_exceeded(tfm, budget):
    _, _, _, params = tfm
    res = plan_placement(params, MappingPlan(), ACFG,
                         tiles_per_context=budget, n_contexts=1)
    if res.overflow:
        assert res.rotation is not None
        for state_names in res.rotation.states():
            assert _packmax_of(res, state_names) <= budget, \
                f"rotation state {state_names} busts budget {budget}"
    else:
        assert res.rotation is None
        assert _packmax_of(res, res.analog) <= budget


def test_more_budget_never_worse(tfm, placed):
    _, _, _, params = tfm
    pred = [plan_placement(params, MappingPlan(), ACFG, tiles_per_context=b,
                           n_contexts=1).predicted_s
            for b in (1, 2, 3, 4, 6, 8)]
    assert all(a >= b - 1e-15 for a, b in zip(pred, pred[1:]))
    # the uncapped result is the floor of the whole sweep
    assert all(p >= placed.predicted_s - 1e-15 for p in pred)


def test_chosen_split_dominates_trivial_splits(tfm):
    _, _, _, params = tfm
    for b in (1, 2, 4, None):
        res = plan_placement(params, MappingPlan(), ACFG,
                             tiles_per_context=b, n_contexts=1)
        assert res.predicted_s <= res.predicted_digital_s + 1e-15
        assert res.predicted_s <= res.predicted_analog_fit_s + 1e-15
        # the prediction helper agrees with the headline numbers
        assert res.predicted_for(()) == pytest.approx(
            res.predicted_digital_s)


# ---------------------------------------------------------------------------
# overflow -> rotation plan invariants
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def overflowed(tfm):
    _, _, _, params = tfm
    res = plan_placement(params, MappingPlan(), ACFG,
                         tiles_per_context=2, n_contexts=1)
    assert res.overflow and res.rotation is not None
    return res


def test_rotation_partitions_the_analog_set(overflowed):
    rot = overflowed.rotation
    assert rot.n_states >= 2
    rotated = [n for g in rot.groups for n in g]
    # hot + rotating groups partition all_names: disjoint, nothing dropped
    assert sorted(rotated) == sorted(set(rotated))
    assert not set(rot.hot) & set(rotated)
    assert set(rot.all_names) == set(rot.hot) | set(rotated)
    # the rotation covers every positive-savings candidate: the resident
    # prefix (`analog`) plus each dropped layer either rotates in or is
    # permanently digital because it cannot fit even alone — nothing is
    # silently dropped
    assert set(overflowed.analog) <= set(rot.all_names)
    pos = {c.path for c in overflowed.costs if c.t_digital > c.t_analog}
    assert set(rot.all_names) | set(rot.digital) == pos
    assert not set(rot.digital) & set(rot.all_names)
    # incoming() cycles over the groups
    for s in range(2 * rot.n_states):
        assert rot.incoming(s) == rot.groups[s % len(rot.groups)]


def test_rotation_plan_programs_uncapped(tfm, overflowed):
    _, _, _, params = tfm
    rot = overflowed.rotation
    plan = rot.plan()
    assert plan.tiles_per_context is None       # one program, all states
    prog = program_model(params, plan, ACFG, jax.random.PRNGKey(1))
    assert set(prog.names) == set(rot.all_names)


def test_singleton_budget_rotates_everything(tfm):
    _, _, _, params = tfm
    res = plan_placement(params, MappingPlan(), ACFG,
                         tiles_per_context=1, n_contexts=1)
    assert res.overflow
    rot = res.rotation
    # nothing fits permanently at budget 1 on this model: all groups are
    # singletons and the hot set is empty
    assert rot.hot == ()
    assert all(len(g) == 1 for g in rot.groups)
    assert rot.n_states == len(rot.all_names)
    assert set(res.analog) <= set(rot.all_names)


def test_rotation_plan_validation():
    with pytest.raises(ValueError):
        RotationPlan(hot=(), groups=(("a",),), digital=(), n_contexts=1,
                     tiles_per_context=1, swap_every=0)


# ---------------------------------------------------------------------------
# the rotating engine: bit-equality, swap billing, compile stability
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def rotating(tfm, overflowed):
    """Rotation engine served on the LOCKED smoke trace."""
    spec, cfg, model, params = tfm
    rot = overflowed.rotation
    prog = program_model(params, rot.plan(), ACFG,
                         jax.random.PRNGKey(SEED + 2))
    rparams = tuple(prog.install_subset(params, ns) for ns in rot.states())
    exe = Execution(mode="aimc", aimc=ACFG, compute_dtype="float32",
                    programmed=True)
    eng = ServeEngine(model, cfg, exe, rparams[0], n_slots=4, prompt_pad=8,
                      max_seq=14, family=spec.family, module=spec.module,
                      program=prog, rotation=rot, rotation_params=rparams)
    counts = eng.warmup()
    reqs = synchronized_trace(4, prompt_len=8, max_new=6, seed=SEED,
                              vocab=cfg.vocab)
    report = eng.serve(reqs)
    return prog, exe, eng, reqs, report, counts


def test_rotating_engine_bit_equal_to_digital_oracle(tfm, rotating):
    _, cfg, model, params = tfm
    _, exe, _, reqs, report, _ = rotating
    prompts = jnp.asarray([r.prompt for r in reqs], jnp.int32)
    dig = dataclasses.replace(exe, mode="digital")
    gen, _ = static_generate(model, cfg, dig, params, prompts, 6,
                             max_seq=14, cache_dtype=jnp.float32)
    for r in reqs:
        assert report.tokens(r.rid) == [int(t) for t in gen[r.rid]], \
            f"req {r.rid} diverged from the digital static oracle"


def test_swap_billing_reconciles_per_event(rotating):
    prog, _, _, _, report, _ = rotating
    assert report.n_swaps > 0
    assert len(report.swap_events) == report.n_swaps
    for ev in report.swap_events:
        assert ev.initialize == prog.reprogram_counts(ev.incoming).initialize
        assert ev.initialize > 0
    assert report.swap_initialize == sum(
        ev.initialize for ev in report.swap_events)
    assert reconcile_swaps(prog, report)
    assert report.wall_swap_s >= 0.0
    # swap chunks are non-decreasing and states advance cyclically
    chunks = [ev.chunk for ev in report.swap_events]
    assert chunks == sorted(chunks)


def test_rotation_never_recompiles_after_warmup(rotating):
    _, _, eng, _, _, counts = rotating
    # one prefill + one decode closure PER rotation state (distinct
    # treedefs), one shared insert
    assert counts == {"prefill": 2, "insert": 1, "decode": 2}
    assert eng.compile_counts() == counts, \
        "rotation swap recompiled an engine closure after warmup"


def test_ledgers_refused_under_rotation(rotating):
    _, _, eng, _, report, _ = rotating
    with pytest.raises(ValueError, match="rotation"):
        eng.ledgers(report)


# ---------------------------------------------------------------------------
# invalid combinations are rejected at construction time
# ---------------------------------------------------------------------------

def _mk(tfm, **kw):
    spec, cfg, model, params = tfm
    kw.setdefault("n_slots", 2)
    kw.setdefault("prompt_pad", 8)
    kw.setdefault("max_seq", 14)
    kw.setdefault("family", spec.family)
    kw.setdefault("module", spec.module)
    return ServeEngine(model, cfg, kw.pop("exe", Execution(
        compute_dtype="float32")), kw.pop("params", params), **kw)


def test_rotation_requires_program_and_matching_params(tfm, overflowed):
    rot = overflowed.rotation
    with pytest.raises(ValueError, match="AimcProgram"):
        _mk(tfm, rotation=rot, rotation_params=(None,) * rot.n_states)


def test_rotation_params_must_match_states(tfm, rotating, overflowed):
    prog, exe, _, _, _, _ = rotating
    rot = overflowed.rotation
    with pytest.raises(ValueError, match="state"):
        _mk(tfm, exe=exe, program=prog, rotation=rot,
            rotation_params=(None,))


@pytest.mark.parametrize("kw", [dict(prefix_cache=True, page_size=4,
                                     n_pages=16),
                                dict(prefill_chunk=4, page_size=4,
                                     n_pages=16)])
def test_rotation_rejects_cached_prefill(tfm, rotating, overflowed, kw):
    prog, exe, _, _, _, _ = rotating
    rot = overflowed.rotation
    rparams = (None,) * rot.n_states
    with pytest.raises(ValueError):
        _mk(tfm, exe=exe, program=prog, rotation=rot,
            rotation_params=rparams, **kw)


def test_sharded_engine_rejects_rotation(tfm, overflowed):
    from repro.runtime.engine import ShardedServeEngine
    spec, cfg, model, params = tfm
    with pytest.raises(ValueError, match="rotation"):
        ShardedServeEngine(model, cfg, Execution(compute_dtype="float32"),
                           params, mesh=None, rotation=overflowed.rotation)


def test_model_server_rejects_rotation_engine(rotating):
    from repro.runtime.server import ModelServer
    from repro.runtime.tenancy import TenantPolicy
    _, _, eng, _, _, _ = rotating
    with pytest.raises(ValueError, match="rotation"):
        ModelServer({"m": eng}, [TenantPolicy("t", "m")])


# ---------------------------------------------------------------------------
# predicted-vs-measured roofline helper
# ---------------------------------------------------------------------------

def test_roofline_fit_recovers_affine_law():
    modeled = [1e-6, 2e-6, 5e-6, 1e-5]
    measured = [3e-6 + 2.0 * t for t in modeled]
    fit = PlacementRoofline.fit(modeled, measured)
    assert fit.t_fixed_s == pytest.approx(3e-6, rel=1e-6)
    assert fit.scale == pytest.approx(2.0, rel=1e-6)
    assert max(fit.residuals(modeled, measured)) < 1e-9
    with pytest.raises(ValueError):
        PlacementRoofline.fit([1e-6], [1e-6])
