"""Paged KV/state cache, prefix cache and chunked prefill
(runtime/engine.py + runtime/pages.py + models.*.prefill_chunk).

The contracts pinned here (ISSUE 9 acceptance criteria):
  * paged decode (mode A and legged) is BIT-EQUAL to the dense engine on
    synchronized AND ragged traces;
  * shapes stay jit-stable: no closure recompiles after warmup, paged or
    legged, on any trace;
  * the page ledger reconciles exactly — every page attributed to exactly
    one owner or the free list at finish();
  * a prefix hit admits WITHOUT re-running the shared span's prefill: the
    producer is billed once, sharers pay only their continuation (CM_*
    ledgers still close exactly against `program.mvm_counts()`);
  * 8 requests sharing one system prompt prefill the shared span exactly
    once (the ci.sh --fast smoke mirrors this through launch.serve);
  * recurrent engines reuse snapshot pages (deepest-boundary restore);
  * pools outlive sessions (prefix pages stay resident across begin());
  * invalid paged configs fail loudly at construction;
  * the sharded engine inherits everything bit-equal on a forced 2-device
    mesh (subprocess, slow).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.aimc import AimcConfig
from repro.core.program import MappingPlan, program_model
from repro.models.layers import Execution
from repro.runtime.batcher import (Request, poisson_trace, reconcile,
                                   synchronized_trace)
from repro.runtime.engine import ServeEngine

EXE = Execution(compute_dtype="float32")


@pytest.fixture(scope="module")
def tfm():
    spec = get_arch("granite-8b")
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    return spec, cfg, model, params


def make_engine(tfm, **kw):
    spec, cfg, model, params = tfm
    kw.setdefault("n_slots", 3)
    kw.setdefault("prompt_pad", 8)
    kw.setdefault("max_seq", 24)
    kw.setdefault("family", spec.family)
    kw.setdefault("module", spec.module)
    return ServeEngine(model, cfg, EXE, kw.pop("params", params), **kw)


def shared_prompt_trace(n, shared, suffix_len, vocab, max_new=5, seed=0):
    """n requests sharing one system prompt + a unique per-request tail."""
    import random
    rng = random.Random(seed)
    out = []
    for i in range(n):
        tail = tuple(rng.randint(1, vocab - 1) for _ in range(suffix_len))
        out.append(Request(rid=i, prompt=tuple(shared) + tail,
                           max_new=max_new, arrival=0.0))
    return out


# ---------------------------------------------------------------------------
# mode A: paged cache, dense prefill — bit-equality + ledger
# ---------------------------------------------------------------------------

def test_mode_a_bit_equal_sync_and_pages_all_freed(tfm):
    spec, cfg, model, params = tfm
    dense = make_engine(tfm)
    dense.warmup()
    paged = make_engine(tfm, page_size=4)
    assert paged.warmup() == {"prefill": 1, "insert": 1, "decode": 1}
    reqs = synchronized_trace(3, prompt_len=8, max_new=6, seed=1,
                              vocab=cfg.vocab)
    r1 = dense.serve(list(reqs))
    r2 = paged.serve(list(reqs))
    for r in reqs:
        assert r1.tokens(r.rid) == r2.tokens(r.rid), \
            f"req {r.rid}: paged decode diverged from dense"
    # no prefix cache: at finish every page is back on the free list
    assert r2.page_ledger_exact
    assert r2.page_ledger["held"] == 0
    assert r2.page_ledger["free"] == paged.pages.n_pages - 1
    assert r2.observed_vectors == r2.useful_vectors


def test_mode_a_ragged_bit_equal_no_recompile(tfm):
    spec, cfg, model, params = tfm
    dense = make_engine(tfm)
    dense.warmup()
    paged = make_engine(tfm, page_size=4)
    counts = paged.warmup()
    reqs = poisson_trace(10, rate=400.0, seed=5, prompt_len=(2, 8),
                         max_new=(1, 7), vocab=cfg.vocab)
    r1 = dense.serve(list(reqs))
    r2 = paged.serve(list(reqs))
    for r in reqs:
        assert r1.tokens(r.rid) == r2.tokens(r.rid), \
            f"req {r.rid}: paged decode diverged on the ragged trace"
    assert paged.compile_counts() == counts, \
        "ragged trace recompiled a paged closure after warmup"
    assert r2.page_ledger_exact and r2.page_ledger["held"] == 0


# ---------------------------------------------------------------------------
# prefix cache: shared span prefilled exactly once, billed exactly once
# ---------------------------------------------------------------------------

def test_prefix_shared_prompt_exactly_once_bit_equal_programmed(tfm):
    spec, cfg, model, params = tfm
    aimc = AimcConfig(impl="ref", input_scale=0.1)
    exe = Execution(mode="aimc", aimc=aimc, compute_dtype="float32",
                    programmed=True)
    program = program_model(params, MappingPlan(), aimc,
                            jax.random.PRNGKey(3))
    installed = program.install(params)
    kw = dict(n_slots=3, prompt_pad=12, max_seq=24, family=spec.family,
              module=spec.module, program=program)
    dense = ServeEngine(model, cfg, exe, installed, **kw)
    dense.warmup()
    paged = ServeEngine(model, cfg, exe, installed, page_size=4,
                        prefix_cache=True, **kw)
    counts = paged.warmup()
    assert counts["prefill_chunk"] == 1 and counts["register"] == 1
    shared = tuple(range(1, 9))                    # 8 tokens = 2 full pages
    reqs = shared_prompt_trace(8, shared, suffix_len=3, vocab=cfg.vocab,
                               max_new=4, seed=2)
    r1 = dense.serve(list(reqs))
    r2 = paged.serve(list(reqs))
    for r in reqs:
        assert r1.tokens(r.rid) == r2.tokens(r.rid), \
            f"req {r.rid}: prefix-cache serving changed the output"
    # exactly-once: the producer pays the full prompt, every sharer only
    # its continuation past the 2 shared pages
    recs = r2.records
    assert recs[0].prefill_vectors == 11
    for i in range(1, 8):
        assert recs[i].prefill_vectors == 11 - 8, \
            f"req {i} re-prefilled the shared span"
    assert r2.prefix_hits == 7
    assert r2.prefix_hit_vectors == 7 * 8
    # never double-billed, never free: the books still close exactly
    assert r2.observed_vectors == r2.useful_vectors
    ledger_sum, static = reconcile(program, recs, r2.observed_vectors)
    assert ledger_sum == static
    # page ledger exact; only the cached prefix pages stay held
    assert r2.page_ledger_exact
    assert r2.page_ledger["held"] == len(paged.prefix)
    assert paged.compile_counts() == counts


def test_prefix_pool_outlives_session(tfm):
    spec, cfg, model, params = tfm
    eng = make_engine(tfm, n_slots=2, page_size=4, prefix_cache=True)
    eng.warmup()
    shared = tuple(range(3, 11))
    reqs = shared_prompt_trace(2, shared, suffix_len=0, vocab=cfg.vocab,
                               max_new=3, seed=4)
    r1 = eng.serve(list(reqs))
    # full-prompt sharing is capped one token short of the prompt (the legs
    # must produce the first-token logits): 8 tokens / P=4 -> 1 page reused
    assert r1.records[1].prefill_vectors == 8 - 4
    # a SECOND session on the same engine still hits: the pool handles and
    # the prefix entries survived finish()/begin()
    reqs2 = shared_prompt_trace(2, shared, suffix_len=0, vocab=cfg.vocab,
                                max_new=3, seed=5)
    r2 = eng.serve(list(reqs2))
    assert r2.prefix_hits == 2                    # both hit this time
    for rec in r2.records.values():
        assert rec.prefill_vectors == 8 - 4
    assert r1.tokens(0) == r2.tokens(0)           # same prompt, same output
    assert r2.page_ledger_exact


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

def test_chunked_prefill_bit_equal_and_cuts_pad_waste(tfm):
    spec, cfg, model, params = tfm
    kw = dict(n_slots=3, prompt_pad=12, max_seq=24)
    dense = make_engine(tfm, **kw)
    dense.warmup()
    paged = make_engine(tfm, page_size=4, prefill_chunk=4, **kw)
    counts = paged.warmup()
    reqs = poisson_trace(10, rate=400.0, seed=9, prompt_len=(2, 12),
                         max_new=(1, 7), vocab=cfg.vocab)
    r1 = dense.serve(list(reqs))
    r2 = paged.serve(list(reqs))
    for r in reqs:
        assert r1.tokens(r.rid) == r2.tokens(r.rid), \
            f"req {r.rid}: chunked prefill changed the output"
    assert paged.compile_counts() == counts
    # a prompt never pays more than one leg's padding; the dense engine
    # pays prompt_pad - len on every prefill
    assert r2.prefill_pad_vectors < r1.prefill_pad_vectors
    assert r2.prefill_chunks >= r2.n_prefills
    assert r2.observed_vectors == r2.useful_vectors
    assert r2.page_ledger_exact and r2.page_ledger["held"] == 0


def test_prefix_plus_chunk_interleaved_books_close(tfm):
    spec, cfg, model, params = tfm
    kw = dict(n_slots=2, prompt_pad=12, max_seq=24)
    dense = make_engine(tfm, **kw)
    dense.warmup()
    paged = make_engine(tfm, page_size=4, prefix_cache=True,
                        prefill_chunk=4, **kw)
    counts = paged.warmup()
    shared = tuple(range(5, 13))
    reqs = shared_prompt_trace(6, shared, suffix_len=4, vocab=cfg.vocab,
                               max_new=4, seed=6)
    r1 = dense.serve(list(reqs))
    r2 = paged.serve(list(reqs))
    for r in reqs:
        assert r1.tokens(r.rid) == r2.tokens(r.rid), \
            f"req {r.rid}: interleaved prefix+chunk serving diverged"
    # interleaved admission cannot promise exactly-once (a follower may be
    # admitted before the producer's last leg registers), but the books
    # and the page ledger must still close exactly
    assert r2.observed_vectors == r2.useful_vectors
    assert r2.page_ledger_exact
    assert r2.page_ledger["held"] == len(paged.prefix)
    assert paged.compile_counts() == counts


# ---------------------------------------------------------------------------
# recurrent snapshot pages
# ---------------------------------------------------------------------------

def test_recurrent_snapshot_hit_bit_equal():
    spec = get_arch("xlstm-350m")
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    kw = dict(n_slots=2, prompt_pad=6, max_seq=16, family=spec.family,
              module=spec.module, cache_dtype=jnp.float32)
    dense = ServeEngine(model, cfg, EXE, params, **kw)
    dense.warmup()
    snap = ServeEngine(model, cfg, EXE, params, page_size=2,
                       prefix_cache=True, **kw)
    counts = snap.warmup()
    assert counts["snapshot"] == 1 and counts["restore"] == 1
    shared = (3, 7, 11, 2, 9, 5)
    reqs = [Request(rid=i, prompt=shared, max_new=4) for i in range(2)]
    r1 = dense.serve(list(reqs))
    r2 = snap.serve(list(reqs))
    for r in reqs:
        assert r1.tokens(r.rid) == r2.tokens(r.rid), \
            f"req {r.rid}: snapshot restore changed the output"
    # deepest usable snapshot: page boundary 4 of a 6-token prompt (the
    # boundary at 6 is capped — the continuation must keep >= 1 token)
    assert r2.records[0].prefill_vectors == 6
    assert r2.records[1].prefill_vectors == 2
    assert r2.prefix_hits == 1 and r2.prefix_hit_vectors == 4
    assert r2.observed_vectors == r2.useful_vectors
    assert r2.page_ledger_exact
    assert snap.compile_counts() == counts


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------

def test_paged_config_validation(tfm):
    with pytest.raises(ValueError, match="require page_size"):
        make_engine(tfm, prefix_cache=True)
    with pytest.raises(ValueError, match="require page_size"):
        make_engine(tfm, prefill_chunk=4)
    with pytest.raises(ValueError, match="> max_seq"):
        make_engine(tfm, page_size=64)
    with pytest.raises(ValueError, match="max-length request"):
        make_engine(tfm, page_size=4, n_pages=4)   # needs 24/4 + 1 = 7
    with pytest.raises(ValueError, match="float32"):
        make_engine(tfm, page_size=4, prefix_cache=True,
                    cache_dtype=jnp.bfloat16)
    # mode A (no legs) serves any cache dtype
    make_engine(tfm, page_size=4, cache_dtype=jnp.bfloat16)


def test_moe_prefix_cache_rejected():
    spec = get_arch("olmoe-1b-7b")
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="MoE"):
        ServeEngine(model, cfg, EXE, params, n_slots=2, prompt_pad=8,
                    max_seq=16, family=spec.family, module=spec.module,
                    page_size=4, prefix_cache=True)


# ---------------------------------------------------------------------------
# encdec paged helpers (unit: the engine rejects the audio family, but the
# decoder self-attn pools must gather identically to the dense cache)
# ---------------------------------------------------------------------------

def test_encdec_paged_view_matches_dense_layout():
    from repro.models import encdec
    spec = get_arch("seamless-m4t-large-v2")
    cfg = spec.smoke_cfg
    pools = encdec.init_paged_cache(cfg, n_pages=5, page_size=2,
                                    dtype=jnp.float32)
    n_dec = pools["kp"].shape[0]
    assert pools["kp"].shape[1:3] == (5, 2)
    key = jax.random.PRNGKey(1)
    kp = jax.random.normal(key, pools["kp"].shape)
    vp = jax.random.normal(key, pools["vp"].shape)
    pt = jnp.asarray([[1, 3], [4, 2]], jnp.int32)      # 2 slots, 2 pages
    k, v = encdec.paged_view(kp, vp, pt, max_seq=4)
    assert k.shape[:3] == (n_dec, 2, 4)
    # the gathered view IS the named pages, row-for-row
    assert jnp.array_equal(k[:, 0, :2], kp[:, 1])
    assert jnp.array_equal(k[:, 0, 2:], kp[:, 3])
    assert jnp.array_equal(v[:, 1, :2], vp[:, 4])
    assert jnp.array_equal(v[:, 1, 2:], vp[:, 2])


# ---------------------------------------------------------------------------
# multi-tenant server: paged registry + per-tenant page quotas
# ---------------------------------------------------------------------------

def test_tenant_policy_max_pages_validation():
    from repro.runtime.tenancy import TenantPolicy
    with pytest.raises(ValueError, match="max_pages"):
        TenantPolicy(name="t", model="m", max_pages=0)
    TenantPolicy(name="t", model="m", max_pages=3)     # positive is fine


def test_server_paged_bit_equal_and_page_quota_blocks_hog():
    from repro.runtime.server import ModelSpec, build_server
    from repro.runtime.tenancy import TenantPolicy, TenantRequest

    def reqs(tenant, rids, vocab):
        import random
        rng = random.Random(7)
        return [TenantRequest(tenant=tenant, request=Request(
            rid=r, prompt=tuple(rng.randint(1, vocab - 1) for _ in range(8)),
            max_new=4, arrival=0.0)) for r in rids]

    kw = dict(smoke=True, n_slots=2, prompt_pad=8, max_seq=16, seed=0)
    srv_d = build_server([ModelSpec(name="lm", arch="granite-8b")], **kw)
    srv_d.warmup()
    srv_p = build_server([ModelSpec(name="lm", arch="granite-8b")],
                         page_size=4, prefix_cache=True, **kw)
    assert srv_p.engines["lm"].prefix is not None
    srv_p.warmup()
    vocab = srv_p.engines["lm"].cfg.vocab
    trace = reqs("lm", range(4), vocab)
    r1 = srv_d.serve(list(trace))
    r2 = srv_p.serve(list(trace))
    for tr in trace:
        rid = tr.request.rid
        assert (r1.model_reports["lm"].tokens(rid)
                == r2.model_reports["lm"].tokens(rid)), \
            f"req {rid}: paged serving through the server diverged"
    assert all(v in (True, None) for v in srv_p.reconcile(r2).values())

    # a tenant whose quota cannot cover even ONE request is never admitted;
    # the co-tenant (no quota) is served normally — candidate elimination,
    # not a drop or a stall
    tenants = [TenantPolicy(name="hog", model="lm", max_pages=1),
               TenantPolicy(name="ok", model="lm")]
    srv_q = build_server([ModelSpec(name="lm", arch="granite-8b")],
                         tenants, page_size=4, **kw)
    srv_q.warmup()
    trace = (reqs("hog", range(0, 3), vocab)
             + reqs("ok", range(10, 13), vocab))
    rep = srv_q.serve(list(trace))
    served = set(rep.model_reports["lm"].records)
    assert served == {10, 11, 12}, \
        f"quota should block every hog request, served {sorted(served)}"
    assert all(v in (True, None) for v in srv_q.reconcile(rep).values())


# ---------------------------------------------------------------------------
# the acceptance bar: sharded paged serving, forced 2-device mesh
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_paged_bit_equal_across_two_devices():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=2 "
            + os.environ.get("XLA_FLAGS", ""))
        import jax, jax.numpy as jnp
        assert jax.device_count() == 2, jax.devices()
        from repro.configs import get_arch
        from repro.launch.mesh import make_mesh
        from repro.models.layers import Execution
        from repro.runtime.batcher import poisson_trace, synchronized_trace
        from repro.runtime.engine import ServeEngine, ShardedServeEngine

        spec = get_arch("granite-8b"); cfg = spec.smoke_cfg
        model = spec.model_module()
        params = model.init(jax.random.PRNGKey(0), cfg)
        exe = Execution(compute_dtype="float32")

        def check(shape, paged_kw, trace):
            mesh = make_mesh(shape, ("data", "model"))
            kw = dict(n_slots=2, prompt_pad=8, max_seq=20,
                      family=spec.family, module=spec.module, **paged_kw)
            e1 = ServeEngine(model, cfg, exe, params, **kw); e1.warmup()
            e2 = ShardedServeEngine(model, cfg, exe, params, mesh=mesh,
                                    **kw)
            counts = e2.warmup()
            r1 = e1.serve(list(trace)); r2 = e2.serve(list(trace))
            for r in trace:
                assert r1.tokens(r.rid) == r2.tokens(r.rid), \\
                    (shape, paged_kw, r.rid)
            assert e2.compile_counts() == counts, (shape, paged_kw)
            assert r2.page_ledger_exact, (shape, paged_kw)
            assert r2.observed_vectors == r2.useful_vectors

        sync = synchronized_trace(4, prompt_len=8, max_new=6, seed=1,
                                  vocab=cfg.vocab)
        ragged = poisson_trace(6, rate=300.0, seed=6, prompt_len=(3, 8),
                               max_new=(1, 5), vocab=cfg.vocab)
        check((2, 1), dict(page_size=4), sync)              # mode A, data
        check((2, 1), dict(page_size=4), ragged)
        check((1, 2), dict(page_size=4), sync)              # mode A, model
        check((2, 1), dict(page_size=4, prefix_cache=True,
                           prefill_chunk=4), ragged)        # legged
        print("SHARDED_PAGED_BITEQUAL_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        ["src", env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_PAGED_BITEQUAL_OK" in proc.stdout


# ---------------------------------------------------------------------------
# eviction pressure: pool sized near ONE max-length request (ISSUE 10)
# ---------------------------------------------------------------------------

def test_page_pressure_tight_pool_serves_everything(tfm):
    """A pool barely bigger than one max-length request: admission must
    defer on `can_admit`, evict cache-only prefix entries LRU-first, and
    still serve the whole ragged trace bit-equal to the dense engine —
    no lost request, exact ledger, zero held pages at the end."""
    spec, cfg, model, params = tfm
    dense = make_engine(tfm)
    dense.warmup()
    # max request span: prompt 8 + 6 decode steps = 14 tokens -> 4 pages
    # of 4; a 6-page pool (+1 scratch) holds one request plus a sliver
    kw = dict(page_size=4, n_pages=7, prefix_cache=True)
    paged = make_engine(tfm, **kw)
    counts = paged.warmup()
    reqs = poisson_trace(10, rate=400.0, seed=5, prompt_len=(2, 8),
                         max_new=(1, 7), vocab=cfg.vocab)
    r1 = dense.serve(list(reqs))
    r2 = paged.serve(list(reqs))
    assert set(r2.records) == {r.rid for r in reqs}      # nobody lost
    for r in reqs:
        assert r1.tokens(r.rid) == r2.tokens(r.rid), \
            f"req {r.rid} diverged under page pressure"
    # exact books; the only pages still held are live cache entries (the
    # prefix pool outlives the session by design)
    assert r2.page_ledger_exact
    stats = paged.prefix.stats()
    assert r2.page_ledger["held"] == stats["entries"]
    assert paged.compile_counts() == counts, \
        "page-pressure eviction recompiled a closure"
    assert stats["evictions"] > 0, \
        "pool this tight must actually evict (test lost its pressure)"

    # deterministic eviction order: an identical fresh engine replays the
    # exact same eviction schedule and token streams
    paged2 = make_engine(tfm, **kw)
    paged2.warmup()
    r3 = paged2.serve(list(reqs))
    for r in reqs:
        assert r2.tokens(r.rid) == r3.tokens(r.rid), r.rid
    assert paged2.prefix.stats() == stats
    assert r3.page_ledger == r2.page_ledger


# ---------------------------------------------------------------------------
# the chunked-admission prefix race, pinned (ISSUE 10 satellite)
# ---------------------------------------------------------------------------

def _race_engines(tfm):
    kw = dict(n_slots=2, prompt_pad=12, max_seq=24)
    dense = make_engine(tfm, **kw)
    dense.warmup()
    paged = make_engine(tfm, page_size=4, prefix_cache=True,
                        prefill_chunk=4, **kw)
    counts = paged.warmup()
    shared = tuple(range(5, 13))                   # 8 tokens = 2 full pages
    reqs = shared_prompt_trace(2, shared, suffix_len=4, vocab=tfm[1].vocab,
                               max_new=4, seed=6)
    return dense, paged, counts, reqs


def test_chunked_prefix_race_tokens_and_billing_pinned(tfm):
    """TWO simultaneous producers of the same span under CHUNKED
    admission: both slots admit before either producer's last leg
    registers the span, so both prefill it in full. The race is benign
    for OUTPUTS (bit-equal) and for the BOOKS (observed == useful; the
    double work is real work, honestly billed) — this characterization
    pins the exact double-billed vector count so any change to the
    admission/registration ordering shows up here."""
    dense, paged, counts, reqs = _race_engines(tfm)
    r1 = dense.serve(list(reqs))
    r2 = paged.serve(list(reqs))
    for r in reqs:
        assert r1.tokens(r.rid) == r2.tokens(r.rid), \
            f"req {r.rid}: racing producers changed the output"
    # characterization: both producers pay the full 11-vector prompt
    # (12 padded-to-chunk minus the final-position carry), zero hits —
    # the 8 shared-span vectors are billed TWICE
    recs = r2.records
    assert recs[0].prefill_vectors == recs[1].prefill_vectors
    assert r2.prefix_hits == 0
    double_billed = sum(rec.prefill_vectors for rec in recs.values()) \
        - recs[0].prefill_vectors - 4          # 4 = req 1's unique tail + 1
    assert double_billed == 8, \
        f"double-billed span vectors changed: {double_billed}"
    # billed honestly: the device loop observed every extra vector
    assert r2.observed_vectors == r2.useful_vectors
    assert r2.page_ledger_exact
    assert paged.compile_counts() == counts


@pytest.mark.xfail(strict=True, reason="chunked admission cannot promise "
                   "exactly-once: a follower admits before the producer's "
                   "last leg registers the span (documented race)")
def test_chunked_prefix_race_exactly_once_claim(tfm):
    """The exactly-once claim the race BREAKS — xfail(strict): if this
    ever starts passing, admission got a registration barrier and the
    characterization pin above must be retired."""
    _, paged, _, reqs = _race_engines(tfm)
    r2 = paged.serve(list(reqs))
    assert r2.prefix_hits == 1
    assert r2.records[1].prefill_vectors < r2.records[0].prefill_vectors
