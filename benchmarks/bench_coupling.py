"""§VII-B — tight vs loose AIMC coupling, in both views.

1. Analytical (the paper's own experiment): the case-1 MLP mapping executed
   over the I/O bus ("loose") vs per-core private tiles ("tight").
   Paper: loose achieves 4.1x over the digital reference but is up to 3.1x
   slower than tight.

2. JAX/TPU view (the DESIGN.md §2 adaptation): `core.coupling.tight_forward`
   (one fused region, analog-domain intermediates never leave VMEM) vs
   `loose_forward` (optimization_barrier between DAC / crossbar / ADC /
   digital stages -> every intermediate materializes to HBM). Compared on
   HBM bytes from `cost_analysis()` of the lowered computations — the TPU
   mirror of the I/O-bus round-trips.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Check, fmt_t, table
from repro.compat import cost_analysis
from repro.core.aimc import AimcConfig, program_linear
from repro.core.costmodel import HIGH_POWER, evaluate, speedup
from repro.core.coupling import loose_forward, tight_forward
from repro.core.workloads import mlp_workloads

# Regression floor for the staged/fused HBM-byte ratio (BlockSpec-level
# accounting at the canonical 1024x1024 / tile 512 / batch 128 shape).
# Measured 2.21x under kernel v1; kernel v2 (no streamed noise operand,
# fused epilogue) leans the fused side down to 2,629,640 bytes -> 3.49x.
# tests/test_coupling.py guards the same constant so the fused kernel's
# working-set advantage cannot silently erode.
HBM_RATIO_FLOOR = 3.0


def run(verbose: bool = True) -> dict:
    # ---- 1. analytical ------------------------------------------------------
    w = mlp_workloads()
    dig = evaluate(w["dig_1c"], HIGH_POWER)
    tight = evaluate(w["ana_case1"], HIGH_POWER)
    loose = evaluate(w["ana_loose"], HIGH_POWER)
    s_loose, _ = speedup(dig, loose)
    slowdown = loose.time_s / tight.time_s
    if verbose:
        print(table("Tight vs loose coupling — analytical (§VII-B)",
                    ["mapping", "time/inf", "vs digital", "vs tight"],
                    [["digital 1c", fmt_t(dig.time_s), "1.0x", "-"],
                     ["loose (I/O bus)", fmt_t(loose.time_s),
                      f"{s_loose:.1f}x", f"{slowdown:.1f}x slower"],
                     ["tight (ISA ext)", fmt_t(tight.time_s),
                      f"{dig.time_s / tight.time_s:.1f}x", "1.0x"]]))
        print()

    # ---- 2. TPU HBM-traffic accounting (BlockSpec-level) ---------------------
    # numerics of the two paths are identical (tests/test_system.py); the
    # difference is WHERE intermediates live. The fused kernel's traffic
    # follows from its BlockSpecs; the staged path adds a write+read round
    # trip per analog-domain intermediate.
    from repro.core.coupling import hbm_bytes_loose, hbm_bytes_tight
    cfg = AimcConfig(tile_rows=512, impl="ref")
    wmat = jax.random.normal(jax.random.PRNGKey(0), (1024, 1024)) * 0.02
    state = program_linear(wmat, cfg)
    # numerics cross-check on this container
    xv = jax.random.normal(jax.random.PRNGKey(1), (128, 1024))
    dt = float(jnp.max(jnp.abs(tight_forward(state, xv, cfg)
                               - loose_forward(state, xv, cfg))))
    b_tight = hbm_bytes_tight(state, 128)
    b_loose = hbm_bytes_loose(state, 128)
    if verbose:
        print(table("Tight vs loose — HBM bytes per call (TPU adaptation)",
                    ["mapping", "HBM bytes", "ratio", "max |y_t - y_l|"],
                    [["tight (fused kernel)", f"{b_tight:,}", "1.0x",
                      f"{dt:.1e}"],
                     ["loose (HBM-staged)", f"{b_loose:,}",
                      f"{b_loose / b_tight:.2f}x", "-"]]))
        print()

    # ---- 3. measured consistency layer ---------------------------------------
    # wallclock of the two executable paths on this host, plus the backend's
    # own bytes-accessed view of the lowered computations. On CPU the
    # compiler reports identical traffic (no VMEM/HBM split exists), so the
    # BlockSpec accounting above stays the quantitative gap; on TPU the
    # lowered ratio is the measured twin of that accounting.
    meas = {}
    for name, fn in (("tight", tight_forward), ("loose", loose_forward)):
        jitted = jax.jit(lambda v, f=fn: f(state, v, cfg))
        compiled = jitted.lower(xv).compile()
        jax.block_until_ready(jitted(xv))
        t0 = time.perf_counter()
        for _ in range(10):
            y = jitted(xv)
        jax.block_until_ready(y)
        meas[name] = (time.perf_counter() - t0) / 10, \
            cost_analysis(compiled).get("bytes accessed", 0.0)
    t_ratio = meas["loose"][0] / meas["tight"][0]
    bytes_distinct = meas["tight"][1] != meas["loose"][1]
    if verbose:
        rows = [[n, fmt_t(meas[n][0]), f"{meas[n][1]:,.0f}"]
                for n in ("tight", "loose")]
        rows.append(["loose/tight", f"{t_ratio:.2f}x",
                     f"{meas['loose'][1] / max(meas['tight'][1], 1):.2f}x"
                     if bytes_distinct else "1.00x (CPU: no HBM split)"])
        print(table("Tight vs loose — measured (wallclock + lowered bytes)",
                    ["mapping", "wallclock", "bytes accessed"], rows))
        print(f"  predicted loose/tight slowdown (analytical, ARM system): "
              f"{slowdown:.2f}x; BlockSpec HBM ratio: "
              f"{b_loose / b_tight:.2f}x (floor {HBM_RATIO_FLOOR}x)")
        print()
    return {"analytical": (dig, tight, loose),
            "bytes": (b_tight, b_loose),
            "measured": meas, "t_ratio": t_ratio,
            "s_loose": s_loose, "slowdown": slowdown}


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    b_tight, b_loose = results["bytes"]
    return [
        Check("loose speedup over digital (paper: 4.1x)",
              results["s_loose"], 4.1),
        Check("loose slowdown vs tight (paper: up to 3.1x)",
              results["slowdown"], 3.1, rtol=0.2),
        Check("staged(loose) HBM bytes vs fused(tight, kernel v2)",
              b_loose / b_tight, 3.49, rtol=0.15),
        Check(f"HBM byte ratio holds the {HBM_RATIO_FLOOR}x recorded floor",
              min(b_loose / b_tight, HBM_RATIO_FLOOR), HBM_RATIO_FLOOR,
              rtol=0),
    ]


if __name__ == "__main__":
    res = run()
    for c in checks(res):
        print(c.row())
