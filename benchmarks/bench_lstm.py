"""Paper Fig. 10 + Fig. 11 — LSTM exploration (§VIII).

PTB character LSTM, n_h in {256, 512, 750}, digital 1/2/5-core references vs
AIMC cases 1-4. Checks (§VIII headline claims):
  * n_h=750 speedup up to 9.4x / energy 9.3x (high-power),
  * n_h=256 gains only 1.0-1.5x (working set already fits caches),
  * multi-core case 4 is ~10% FASTER than case 1 (unlike the MLP),
  * AIMC run time grows sub-quadratically with n_h (~1.4x avg step),
  * cell dequeue+activation dominates the analog run time (Fig. 11).
"""

from __future__ import annotations

from benchmarks.common import Check, fmt_e, fmt_t, table
from repro.core.costmodel import HIGH_POWER, LOW_POWER, evaluate, speedup
from repro.core.workloads import lstm_workloads

NHS = (256, 512, 750)
CASES = ["dig_1c", "dig_2c", "dig_5c",
         "ana_case1", "ana_case2", "ana_case3", "ana_case4"]


def run(verbose: bool = True) -> dict:
    results = {}
    for sysc in (HIGH_POWER, LOW_POWER):
        res = {}
        for nh in NHS:
            w = lstm_workloads(nh)
            res[nh] = {c: evaluate(w[c], sysc) for c in CASES}
        results[sysc.name] = res
        if verbose:
            rows = []
            for nh in NHS:
                dig = res[nh]["dig_1c"]
                for c in CASES:
                    r = res[nh][c]
                    s, e = speedup(dig, r)
                    rows.append([nh, c, fmt_t(r.time_s), fmt_e(r.energy_j),
                                 f"{s:.1f}x", f"{e:.1f}x"])
            print(table(f"LSTM — {sysc.name} system (Fig. 10)",
                        ["n_h", "case", "time/inf", "energy/inf",
                         "speedup", "energy gain"], rows))
            print()
    if verbose:
        rows = []
        res = results["high-power"]
        for nh in NHS:
            for case in ("ana_case1", "ana_case4"):
                r = res[nh][case]
                tot = sum(r.breakdown.values()) or 1.0
                deq_act = (r.breakdown["analog_dequeue"]
                           + r.breakdown["digital_ops"]) / tot
                q = r.breakdown["analog_queue"] / tot
                rows.append([nh, case, f"{deq_act:.0%}", f"{q:.0%}"])
        print(table("LSTM sub-ROI shares, high-power (Fig. 11)",
                    ["n_h", "case", "dequeue+activation", "queue"], rows))
        print()
    return results


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    hp = results["high-power"]
    s750, e750 = speedup(hp[750]["dig_1c"], hp[750]["ana_case1"])
    s256, _ = speedup(hp[256]["dig_1c"], hp[256]["ana_case1"])
    # analog run-time growth with n_h (paper: ~1.4x average step)
    t256 = hp[256]["ana_case1"].time_s
    t512 = hp[512]["ana_case1"].time_s
    t750 = hp[750]["ana_case1"].time_s
    growth = ((t512 / t256) + (t750 / t512)) / 2
    r = hp[750]["ana_case1"].breakdown
    share = ((r["analog_dequeue"] + r["digital_ops"])
             / (sum(r.breakdown.values()) if hasattr(r, "breakdown")
                else sum(r.values())))
    return [
        Check("LSTM n_h=750 speedup (high-power)", s750, 9.4),
        Check("LSTM n_h=750 energy gain (high-power)", e750, 9.3),
        Check("LSTM n_h=256 speedup (1.0-1.5x band)", s256, 1.5, rtol=0.45),
        Check("analog run-time growth per size step (~1.4x)", growth, 1.4,
              rtol=0.3),
        Check("case4 ~10% faster than case1 (n_h=750)",
              hp[750]["ana_case1"].time_s / hp[750]["ana_case4"].time_s,
              1.10, rtol=0.15),
        Check("cell dequeue+activation dominates (<=81.8%)", share, 0.75,
              rtol=0.3),
    ]


if __name__ == "__main__":
    res = run()
    for c in checks(res):
        print(c.row())
