"""Paper Fig. 10 + Fig. 11 — LSTM exploration (§VIII).

PTB character LSTM, n_h in {256, 512, 750}, digital 1/2/5-core references vs
AIMC cases 1-4. Checks (§VIII headline claims):
  * n_h=750 speedup up to 9.4x / energy 9.3x (high-power),
  * n_h=256 gains only 1.0-1.5x (working set already fits caches),
  * multi-core case 4 is ~10% FASTER than case 1 (unlike the MLP),
  * AIMC run time grows sub-quadratically with n_h (~1.4x avg step),
  * cell dequeue+activation dominates the analog run time (Fig. 11).
"""

from __future__ import annotations

import time

from benchmarks.common import Check, fmt_e, fmt_t, table
from repro.core.costmodel import HIGH_POWER, LOW_POWER, evaluate, speedup
from repro.core.workloads import lstm_workloads

NHS = (256, 512, 750)
CASES = ["dig_1c", "dig_2c", "dig_5c",
         "ana_case1", "ana_case2", "ana_case3", "ana_case4"]


def run(verbose: bool = True) -> dict:
    results = {}
    for sysc in (HIGH_POWER, LOW_POWER):
        res = {}
        for nh in NHS:
            w = lstm_workloads(nh)
            res[nh] = {c: evaluate(w[c], sysc) for c in CASES}
        results[sysc.name] = res
        if verbose:
            rows = []
            for nh in NHS:
                dig = res[nh]["dig_1c"]
                for c in CASES:
                    r = res[nh][c]
                    s, e = speedup(dig, r)
                    rows.append([nh, c, fmt_t(r.time_s), fmt_e(r.energy_j),
                                 f"{s:.1f}x", f"{e:.1f}x"])
            print(table(f"LSTM — {sysc.name} system (Fig. 10)",
                        ["n_h", "case", "time/inf", "energy/inf",
                         "speedup", "energy gain"], rows))
            print()
    if verbose:
        rows = []
        res = results["high-power"]
        for nh in NHS:
            for case in ("ana_case1", "ana_case4"):
                r = res[nh][case]
                tot = sum(r.breakdown.values()) or 1.0
                deq_act = (r.breakdown["analog_dequeue"]
                           + r.breakdown["digital_ops"]) / tot
                q = r.breakdown["analog_queue"] / tot
                rows.append([nh, case, f"{deq_act:.0%}", f"{q:.0%}"])
        print(table("LSTM sub-ROI shares, high-power (Fig. 11)",
                    ["n_h", "case", "dequeue+activation", "queue"], rows))
        print()
    return results


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    hp = results["high-power"]
    s750, e750 = speedup(hp[750]["dig_1c"], hp[750]["ana_case1"])
    s256, _ = speedup(hp[256]["dig_1c"], hp[256]["ana_case1"])
    # analog run-time growth with n_h (paper: ~1.4x average step)
    t256 = hp[256]["ana_case1"].time_s
    t512 = hp[512]["ana_case1"].time_s
    t750 = hp[750]["ana_case1"].time_s
    growth = ((t512 / t256) + (t750 / t512)) / 2
    r = hp[750]["ana_case1"].breakdown
    share = ((r["analog_dequeue"] + r["digital_ops"])
             / (sum(r.breakdown.values()) if hasattr(r, "breakdown")
                else sum(r.values())))
    return [
        Check("LSTM n_h=750 speedup (high-power)", s750, 9.4),
        Check("LSTM n_h=750 energy gain (high-power)", e750, 9.3),
        Check("LSTM n_h=256 speedup (1.0-1.5x band)", s256, 1.5, rtol=0.45),
        Check("analog run-time growth per size step (~1.4x)", growth, 1.4,
              rtol=0.3),
        Check("case4 ~10% faster than case1 (n_h=750)",
              hp[750]["ana_case1"].time_s / hp[750]["ana_case4"].time_s,
              1.10, rtol=0.15),
        Check("cell dequeue+activation dominates (<=81.8%)", share, 0.75,
              rtol=0.3),
    ]


def run_wallclock(nh: int = 750, steps: int = 16, batch: int = 8,
                  iters: int = 5, verbose: bool = True) -> dict:
    """Measured program-once vs per-call-reprogram decode on the PTB LSTM.

    One decode step == one jitted call, mirroring the serving loop: the
    programmed path holds the four gate matrices stationary (side-by-side
    tenant, §VIII-D — programmed ONCE before the loop); the reprogram path
    re-quantizes + re-programs the cell weights on EVERY step (what
    `serve --exec aimc` paid per token before the program API)."""
    import jax
    import jax.numpy as jnp

    from repro.core.aimc import (AimcConfig, aimc_apply, aimc_linear_ste,
                                 program_linear)
    from repro.models.paper_nets import _lstm_cell_math, lstm_init

    cfg = AimcConfig(tile_rows=512, impl="ref")
    params = lstm_init(jax.random.PRNGKey(0), nh)
    w_cell = jnp.concatenate([params["w_f"], params["w_i"], params["w_g"],
                              params["w_o"]], axis=1)
    xs = jax.random.normal(jax.random.PRNGKey(1), (steps, batch, 50))

    st_cell = program_linear(w_cell, cfg)       # CM_INITIALIZE, once
    st_y = program_linear(params["w_y"], cfg)

    @jax.jit
    def step_programmed(st_cell, st_y, h, c, x_t):
        gates = aimc_apply(st_cell, jnp.concatenate([h, x_t], -1), cfg)
        h, c = _lstm_cell_math(gates, c, nh)
        return h, c, jax.nn.softmax(aimc_apply(st_y, h, cfg), -1)

    @jax.jit
    def step_reprogram(w_cell, w_y, h, c, x_t):
        gates = aimc_linear_ste(jnp.concatenate([h, x_t], -1), w_cell, None,
                                cfg)
        h, c = _lstm_cell_math(gates, c, nh)
        return h, c, jax.nn.softmax(aimc_linear_ste(h, w_y, None, cfg), -1)

    def _loop(step, *weights):
        h = jnp.zeros((batch, nh))
        c = jnp.zeros((batch, nh))
        for t in range(steps):
            h, c, y = step(*weights, h, c, xs[t])
        return y

    def _time(step, *weights):
        jax.block_until_ready(_loop(step, *weights))    # compile + warm
        t0 = time.time()
        for _ in range(iters):
            out = _loop(step, *weights)
        jax.block_until_ready(out)
        return (time.time() - t0) / (iters * steps)

    t_prog = _time(step_programmed, st_cell, st_y)
    t_reprog = _time(step_reprogram, w_cell, params["w_y"])
    out = {"t_programmed": t_prog, "t_reprogram": t_reprog,
           "speedup": t_reprog / t_prog}
    if verbose:
        print(table(f"LSTM n_h={nh} measured decode, batch={batch} "
                    f"(simulated crossbars, this host, per step)",
                    ["path", "time/step", "vs reprogram"],
                    [["program-once (apply)", fmt_t(t_prog),
                      f"{out['speedup']:.2f}x"],
                     ["per-step reprogram (seed)", fmt_t(t_reprog), "1.0x"]]))
        print()
    return out


if __name__ == "__main__":
    res = run()
    run_wallclock()
    for c in checks(res):
        print(c.row())
