"""Pallas kernel microbenchmarks (interpret mode on this CPU container).

Wall-clock numbers here are *interpreter* times — meaningless as TPU
performance, reported only to show the harness. The meaningful outputs:

  (a) kernel-vs-oracle agreement across a shape sweep, noise ON (the
      in-kernel counter PRNG must match the oracle's bulk draw);
  (b) the kernel-v2 HBM-traffic ledger per call vs kernel v1 for the
      paper's MLP/LSTM/CNN layer shapes: the `[KB, B, Np]` noise operand is
      GONE (a 4-byte scalar seed replaces it) and the epilogue's separate
      bias/activation op round-trip is fused away;
  (c) the VMEM working-set accounting of the chosen BlockSpecs (no noise
      block under v2), checked against the 16 MB budget;
  (d) fused-epilogue and gate-fused-stack exactness checks.

`run()` returns a JSON-serializable dict — `python -m benchmarks.run --json
BENCH_kernels.json` persists it for cross-PR perf tracking, and `ci.sh
--fast` replays it as a perf-smoke gate.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Check, table
from repro.core.aimc import AimcConfig, program_linear
from repro.core.coupling import (hbm_bytes_tight, hbm_epilogue_bytes,
                                 hbm_noise_bytes)
from repro.core.noise import NoiseModel, read_sigma_lsb
from repro.core.quant import sym_scale
from repro.kernels import ops, ref

SHAPES = [  # (B, K, N) — kernel-vs-oracle parity sweep
    (8, 256, 256),
    (64, 1024, 1024),
    (128, 512, 2048),
    (16, 300, 200),      # ragged -> padding path
]

# The paper's exploration-layer shapes (MLP Fig. 6, LSTM n_h=750 Table II,
# CNN-F conv2 im2col) at single-inference and batched serving sizes.
PAPER_SHAPES = [  # (name, B, K, N)
    ("mlp_fc 1024x1024 b=1", 1, 1024, 1024),
    ("mlp_fc 1024x1024 b=128", 128, 1024, 1024),
    ("lstm_cell n_h=750 b=1", 1, 800, 3000),
    ("cnn_conv2 5x5x64->256", 64, 1600, 256),
]

NOISY = NoiseModel(sigma_read=0.005)


def vmem_bytes(bb: int, m: int, bn: int) -> int:
    """Per-grid-step VMEM working set of the v2 kernel (no noise block —
    noise is generated in registers/VMEM from the prefetched seed)."""
    return (bb * m * 4          # x block f32
            + m * bn * 1        # stationary int8 weight panel
            + bb * bn * 4       # output block f32
            + bn * 4 + 4        # s_w row + s_x scalar
            + 4)                # prefetched seed


def _traffic_row(state, b: int):
    """Per-call HBM bytes under the v1 contract (streamed noise + separate
    epilogue op) vs kernel v2 (scalar seed + fused epilogue)."""
    v1 = hbm_bytes_tight(state, b, noise_streamed=True, epilogue_fused=False)
    v2 = hbm_bytes_tight(state, b, noise_streamed=False, epilogue_fused=True)
    return {
        "v1_bytes": int(v1),
        "v2_bytes": int(v2),
        "noise_bytes_v1": int(hbm_noise_bytes(state, b, noise_streamed=True)),
        "noise_operand_bytes_v2": 0,    # no [KB, B, Np] operand exists
        "seed_bytes_v2": int(hbm_noise_bytes(state, b, noise_streamed=False)),
        "epilogue_bytes_v1": int(hbm_epilogue_bytes(state, b,
                                                    epilogue_fused=False)),
        "epilogue_bytes_v2": 0,
        "ratio": float(v1 / v2),
    }


def jaxpr_materializes_shape(jaxpr, shape) -> bool:
    """True if any value of `shape` flows anywhere in the computation —
    recursing into nested jaxprs (pjit/scan/pallas bodies), so a noise
    tensor rematerialized INSIDE the jitted kernel wrapper is still seen."""
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            if getattr(getattr(v, "aval", None), "shape", None) == shape:
                return True
        for param in eqn.params.values():
            inner = getattr(param, "jaxpr", None)   # ClosedJaxpr -> Jaxpr
            if inner is None and hasattr(param, "eqns"):
                inner = param                        # raw Jaxpr
            if inner is not None and jaxpr_materializes_shape(inner, shape):
                return True
    return False


def _noise_operand_absent(state, xf, s_x, cfg, sigma) -> bool:
    """Structural check: no [KB, B, Np]-shaped value exists anywhere in the
    lowered v2 computation even with noise enabled."""
    kb, m, np_ = state.w_q.shape
    shape = (kb, xf.shape[0], np_)
    jaxpr = jax.make_jaxpr(
        lambda xv, seed: ops.aimc_matmul_v2(
            xv, state.w_q, state.s_w, s_x, seed, adc_step=cfg.adc_step,
            sigma=sigma, impl="pallas_interpret"))(xf, jnp.uint32(1))
    return not jaxpr_materializes_shape(jaxpr.jaxpr, shape)


def run(verbose: bool = True) -> dict:
    cfg = AimcConfig(tile_rows=256, impl="ref", noise=NOISY)
    sigma = read_sigma_lsb(cfg.tile_rows, NOISY)
    seed = jnp.uint32(0xA11CE)

    # ---- (a) kernel vs oracle, in-kernel noise ON ---------------------------
    rows, max_err, cases = [], 0.0, []
    for (b, k, n) in SHAPES:
        kx, kw = jax.random.split(jax.random.PRNGKey(b + k + n))
        x = jax.random.normal(kx, (b, k), jnp.float32)
        w = jax.random.normal(kw, (k, n), jnp.float32) * 0.05
        st = program_linear(w, cfg)
        kb, m, np_ = st.w_q.shape
        xf = jnp.pad(x, ((0, 0), (0, kb * m - k)))
        s_x = sym_scale(xf).reshape(1, 1)

        y_ref = ops.aimc_matmul_v2(xf, st.w_q, st.s_w, s_x, seed,
                                   adc_step=cfg.adc_step, sigma=sigma,
                                   impl="ref")
        t0 = time.perf_counter()
        y_pal = ops.aimc_matmul_v2(xf, st.w_q, st.s_w, s_x, seed,
                                   adc_step=cfg.adc_step, sigma=sigma,
                                   impl="pallas_interpret")
        jax.block_until_ready(y_pal)
        t1 = time.perf_counter()
        err = float(jnp.max(jnp.abs(y_ref - y_pal)))
        max_err = max(max_err, err)
        cases.append({"shape": f"{b}x{k}x{n}", "max_err": err,
                      "interpret_wallclock_s": t1 - t0})
        rows.append([f"{b}x{k}x{n}", f"{err:.2e}",
                     f"{(t1 - t0) * 1e3:.0f}ms (interp)"])
    if verbose:
        print(table("AIMC kernel v2 vs oracle (in-kernel noise ON)",
                    ["B x K x N", "max |kernel - oracle|", "interpret time"],
                    rows))
        print()

    # ---- (b) HBM bytes per call: v1 vs v2, paper layer shapes ---------------
    traffic, rows = [], []
    for name, b, k, n in PAPER_SHAPES:
        st = program_linear(jnp.ones((k, n)) * 0.02, cfg)
        t = {"name": name, "b": b, "k": k, "n": n} | _traffic_row(st, b)
        traffic.append(t)
        rows.append([name, f"{t['v1_bytes']:,}", f"{t['v2_bytes']:,}",
                     f"{t['noise_bytes_v1']:,}",
                     t["noise_operand_bytes_v2"],
                     f"{t['epilogue_bytes_v1']:,}", f"{t['ratio']:.2f}x"])
    if verbose:
        print(table(
            "HBM bytes per call: v1 (streamed noise + separate epilogue) "
            "vs kernel v2",
            ["layer", "v1 total", "v2 total", "v1 noise", "v2 noise operand",
             "v1 epilogue", "v1/v2"], rows))
        print()

    # ---- (c/d) exactness + structural checks --------------------------------
    st = program_linear(
        jax.random.normal(jax.random.PRNGKey(3), (512, 384)) * 0.05, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 512))
    kb, m, np_ = st.w_q.shape
    s_x = sym_scale(x).reshape(1, 1)
    noise_gone = _noise_operand_absent(st, x, s_x, cfg, sigma)

    bias = jax.random.normal(jax.random.PRNGKey(5), (np_,))
    y_fused = ops.aimc_matmul_v2(x, st.w_q, st.s_w, s_x, None, bias,
                                 adc_step=cfg.adc_step, activation="relu",
                                 impl="pallas_interpret")
    y_unf = ops.aimc_matmul_v2(x, st.w_q, st.s_w, s_x,
                               adc_step=cfg.adc_step, impl="pallas_interpret")
    epilogue_exact = bool(jnp.all(
        y_fused == jnp.maximum(y_unf + bias[None, :], 0.0)))

    from repro.kernels import cprng
    w_q = jnp.stack([st.w_q] * 4)
    s_w = jnp.stack([st.s_w] * 4)
    y_stk = ops.aimc_matmul_stacked(x, w_q, s_w, s_x, seed,
                                    adc_step=cfg.adc_step, sigma=sigma,
                                    impl="pallas_interpret")
    stack_exact = all(bool(jnp.all(
        y_stk[g] == ops.aimc_matmul_v2(x, st.w_q, st.s_w, s_x,
                                       cprng.stack_seed(seed, g),
                                       adc_step=cfg.adc_step, sigma=sigma,
                                       impl="pallas_interpret")))
        for g in range(4))

    vm = vmem_bytes(128, 512, 512)
    if verbose:
        print(f"  noise [KB,B,Np] operand absent under v2 (noise on): "
              f"{noise_gone}")
        print(f"  fused epilogue == separate bias/relu ops: {epilogue_exact}")
        print(f"  gate-fused stack == per-gate calls (noise on): "
              f"{stack_exact}")
        print(f"  default BlockSpec VMEM working set: {vm / 2**20:.2f} MiB "
              f"(budget 16 MiB)")
        print()
    return {"max_err": max_err, "vmem": vm, "cases": cases,
            "hbm_traffic": traffic, "noise_operand_gone": noise_gone,
            "epilogue_exact": epilogue_exact, "stack_exact": stack_exact}


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    min_ratio = min(t["ratio"] for t in results["hbm_traffic"])
    worst_noise = max(t["noise_operand_bytes_v2"]
                      for t in results["hbm_traffic"])
    return [
        Check("kernel-oracle max abs err < 1e-5 (noise on)",
              1.0 if results["max_err"] < 1e-5 else 0.0, 1.0, rtol=0.01),
        Check("VMEM working set under 16 MiB",
              1.0 if results["vmem"] < 16 * 2**20 else 0.0, 1.0, rtol=0.01),
        Check("v2 noise-path HBM input bytes == 0 (no [KB,B,Np] operand)",
              1.0 if (worst_noise == 0 and results["noise_operand_gone"])
              else 0.0, 1.0, rtol=0.01),
        Check("fused epilogue == separate bias/activation ops",
              1.0 if results["epilogue_exact"] else 0.0, 1.0, rtol=0.01),
        Check("gate-fused stack == per-gate calls (noise on)",
              1.0 if results["stack_exact"] else 0.0, 1.0, rtol=0.01),
        Check("v1/v2 HBM bytes ratio > 1 on every paper layer",
              1.0 if min_ratio > 1.0 else 0.0, 1.0, rtol=0.01),
    ]


if __name__ == "__main__":
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH",
                    help="write results + checks as JSON")
    args = ap.parse_args()
    res = run()
    cs = checks(res)
    for c in cs:
        print(c.row())
    if args.json:
        payload = {"results": res,
                   "checks": [{"name": c.name, "measured": c.measured,
                               "target": c.target, "ok": c.ok} for c in cs]}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")
    sys.exit(0 if all(c.ok for c in cs) else 1)
