"""Pallas kernel microbenchmarks (interpret mode on this CPU container).

Wall-clock numbers here are *interpreter* times — meaningless as TPU
performance, reported only to show the harness. The meaningful output is
(a) kernel-vs-oracle agreement across a shape sweep and (b) the VMEM
working-set accounting of the chosen BlockSpecs, checked against the 16 MB
budget the kernel claims in its docstring.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Check, table
from repro.core.aimc import AimcConfig, program_linear
from repro.kernels import ops, ref

SHAPES = [  # (B, K, N)
    (8, 256, 256),
    (64, 1024, 1024),
    (128, 512, 2048),
    (16, 300, 200),      # ragged -> padding path
]


def vmem_bytes(bb: int, m: int, bn: int) -> int:
    """Per-grid-step VMEM working set of kernels/aimc_mvm.py."""
    return (bb * m * 4          # x block f32
            + m * bn * 1        # stationary int8 weight panel
            + bb * bn * 4       # read-noise block f32
            + bb * bn * 4       # output block f32
            + bn * 4 + 4)       # s_w row + s_x scalar


def run(verbose: bool = True) -> dict:
    cfg = AimcConfig(tile_rows=256, impl="ref")
    rows, max_err = [], 0.0
    for (b, k, n) in SHAPES:
        kx, kw = jax.random.split(jax.random.PRNGKey(b + k + n))
        x = jax.random.normal(kx, (b, k), jnp.float32)
        w = jax.random.normal(kw, (k, n), jnp.float32) * 0.05
        st = program_linear(w, cfg)
        kb, m, np_ = st.w_q.shape
        from repro.core.quant import sym_scale
        xf = jnp.pad(x, ((0, 0), (0, kb * m - k)))
        s_x = sym_scale(xf).reshape(1, 1)
        noise = jnp.zeros((kb, b, np_), jnp.float32)

        y_ref = ops.aimc_matmul(xf, st.w_q, st.s_w, s_x, noise,
                                adc_step=cfg.adc_step, impl="ref")
        t0 = time.perf_counter()
        y_pal = ops.aimc_matmul(xf, st.w_q, st.s_w, s_x, noise,
                                adc_step=cfg.adc_step,
                                impl="pallas_interpret")
        jax.block_until_ready(y_pal)
        t1 = time.perf_counter()
        err = float(jnp.max(jnp.abs(y_ref - y_pal)))
        max_err = max(max_err, err)
        rows.append([f"{b}x{k}x{n}", f"{err:.2e}",
                     f"{(t1 - t0) * 1e3:.0f}ms (interp)"])
    vm = vmem_bytes(128, 512, 512)
    if verbose:
        print(table("AIMC crossbar kernel vs oracle", ["B x K x N",
                    "max |kernel - oracle|", "interpret time"], rows))
        print(f"  default BlockSpec VMEM working set: {vm / 2**20:.2f} MiB "
              f"(budget 16 MiB)")
        print()
    return {"max_err": max_err, "vmem": vm}


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    return [
        Check("kernel-oracle max abs err < 1e-5",
              1.0 if results["max_err"] < 1e-5 else 0.0, 1.0, rtol=0.01),
        Check("VMEM working set under 16 MiB",
              1.0 if results["vmem"] < 16 * 2**20 else 0.0, 1.0, rtol=0.01),
    ]


if __name__ == "__main__":
    res = run()
    for c in checks(res):
        print(c.row())
