"""Paper Fig. 7 + Fig. 8 — multi-layer perceptron exploration (§VII).

Reproduces, per system configuration (high-power / low-power):
  * total time, energy and memory intensity for the digital 1/2/4-core
    references and AIMC cases 1-4 (Fig. 7);
  * the sub-ROI run-time breakdown of the analog cases (Fig. 8);
  * the paper's §VII headline claims, checked in `checks()`:
      - max speedup 12.8x / energy 12.5x (high-power, case 1),
      - case 1 beats case 2 by a slight margin,
      - multi-core is SLOWER: case 1 ~20% better than case 3, ~30% than 4,
      - low-power gains are smaller than high-power gains.
"""

from __future__ import annotations

import time

from benchmarks.common import Check, fmt_e, fmt_t, table
from repro.core.costmodel import CALIB, HIGH_POWER, LOW_POWER, evaluate, speedup
from repro.core.workloads import mlp_workloads

CASES = ["dig_1c", "dig_2c", "dig_4c",
         "ana_case1", "ana_case2", "ana_case3", "ana_case4"]


def run(verbose: bool = True) -> dict:
    w = mlp_workloads()
    results = {}
    for sysc in (HIGH_POWER, LOW_POWER):
        res = {c: evaluate(w[c], sysc) for c in CASES}
        results[sysc.name] = res
        if verbose:
            rows = []
            dig = res["dig_1c"]
            for c in CASES:
                r = res[c]
                s, e = speedup(dig, r)
                rows.append([c, fmt_t(r.time_s), fmt_e(r.energy_j),
                             f"{r.llc_mpi * 1e3:.3f}", f"{s:.1f}x", f"{e:.1f}x"])
            print(table(f"MLP (1024,1024) — {sysc.name} system (Fig. 7)",
                        ["case", "time/inf", "energy/inf", "LLCMPI(e-3)",
                         "speedup", "energy gain"], rows))
            print()
    # Fig. 8 — sub-ROI breakdown, averaged across systems, analog case 1
    if verbose:
        rows = []
        for case in ("dig_1c", "ana_case1", "ana_case3", "ana_case4"):
            shares = {}
            for sysc in (HIGH_POWER, LOW_POWER):
                r = results[sysc.name][case]
                tot = sum(r.breakdown.values()) or 1.0
                for k, v in r.breakdown.items():
                    shares[k] = shares.get(k, 0.0) + v / tot / 2
            top = sorted(shares.items(), key=lambda kv: -kv[1])[:4]
            rows.append([case] + [f"{k}={v:.0%}" for k, v in top])
        print(table("MLP sub-ROI time shares (Fig. 8)",
                    ["case", "1st", "2nd", "3rd", "4th"], rows))
        print()
    return results


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    hp, lp = results["high-power"], results["low-power"]
    s1, e1 = speedup(hp["dig_1c"], hp["ana_case1"])
    s1l, _ = speedup(lp["dig_1c"], lp["ana_case1"])
    out = [
        Check("MLP max speedup (high-power, case 1)", s1, 12.8),
        Check("MLP max energy gain (high-power, case 1)", e1, 12.5),
        Check("case1 vs case3 run-time advantage (~20%)",
              hp["ana_case3"].time_s / hp["ana_case1"].time_s, 1.20),
        Check("case1 vs case4 run-time advantage (~30%)",
              hp["ana_case4"].time_s / hp["ana_case1"].time_s, 1.30, rtol=0.2),
        Check("case1 beats case2 (slight margin)",
              hp["ana_case2"].time_s / hp["ana_case1"].time_s, 1.2, rtol=0.25),
    ]
    # qualitative: low-power gains < high-power gains
    out.append(Check("low-power gain < high-power gain (ratio)",
                     s1l / s1, 0.65, rtol=0.35))
    return out


def run_wallclock(batch: int = 8, iters: int = 30, verbose: bool = True) -> dict:
    """Measured (not analytical) program-once vs per-call-reprogram timings.

    The simulated-crossbar MLP forward, jitted, on this host: the programmed
    path applies pre-initialized `AimcLinearState`s (the paper's deployment
    model, `core.program`); the reprogram path quantizes + programs both
    weight matrices inside every call (the pre-API behaviour of the model
    zoo's `aimc_linear_ste` hot path)."""
    import jax
    import jax.numpy as jnp

    from repro.core.aimc import (AimcConfig, aimc_apply, aimc_linear_ste,
                                 program_linear)
    from repro.models.paper_nets import mlp_init

    cfg = AimcConfig(tile_rows=512, impl="ref")
    params = mlp_init(jax.random.PRNGKey(0), 1024)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, 1024))

    s1 = program_linear(params["w1"], cfg)      # CM_INITIALIZE, once
    s2 = program_linear(params["w2"], cfg)

    @jax.jit
    def programmed(s1, s2, x):
        h = jax.nn.relu(aimc_apply(s1, x, cfg))
        return jax.nn.relu(aimc_apply(s2, h, cfg))

    @jax.jit
    def reprogram(p, x):
        h = jax.nn.relu(aimc_linear_ste(x, p["w1"], None, cfg))
        return jax.nn.relu(aimc_linear_ste(h, p["w2"], None, cfg))

    def _time(fn, *args):
        jax.block_until_ready(fn(*args))        # compile + warm
        t0 = time.time()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.time() - t0) / iters

    t_prog = _time(programmed, s1, s2, x)
    t_reprog = _time(reprogram, params, x)
    out = {"t_programmed": t_prog, "t_reprogram": t_reprog,
           "speedup": t_reprog / t_prog}
    if verbose:
        print(table(f"MLP (1024,1024) measured inference, batch={batch} "
                    f"(simulated crossbars, this host)",
                    ["path", "time/call", "vs reprogram"],
                    [["program-once (apply)", fmt_t(t_prog),
                      f"{out['speedup']:.2f}x"],
                     ["per-call reprogram (seed)", fmt_t(t_reprog), "1.0x"]]))
        print()
    return out


if __name__ == "__main__":
    res = run()
    run_wallclock()
    for c in checks(res):
        print(c.row())
