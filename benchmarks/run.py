"""Benchmark harness front door: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (DESIGN.md §7):

  bench_mlp       Fig. 7 / Fig. 8   (MLP cases, both systems)
  bench_lstm      Fig. 10 / Fig. 11 (LSTM n_h sweep, cases)
  bench_cnn       Fig. 13 / Fig. 14 (CNN-F/M/S, 8-core pipeline)
  bench_pipeline  §VII-IX           (executable multi-core schedules vs the
                                     cost model: measured-vs-predicted)
  bench_coupling  §VII-B            (tight vs loose, analytical + lowered)
  bench_accuracy  §III-C            (AIMC output fidelity vs digital)
  bench_kernels   kernels/          (Pallas vs oracle + VMEM budget)
  bench_roofline  §Roofline         (dry-run table; run dryrun first)

Exit code 1 if any paper-claim validation fails.
"""

from __future__ import annotations

import sys
import time

from benchmarks import (bench_accuracy, bench_cnn, bench_coupling,
                        bench_kernels, bench_lstm, bench_mlp, bench_pipeline,
                        bench_roofline)

MODULES = [
    ("MLP (paper Fig. 7/8)", bench_mlp),
    ("LSTM (paper Fig. 10/11)", bench_lstm),
    ("CNN (paper Fig. 13/14)", bench_cnn),
    ("Multi-core schedules (measured vs predicted)", bench_pipeline),
    ("Coupling (paper §VII-B)", bench_coupling),
    ("Fidelity (paper §III-C)", bench_accuracy),
    ("Pallas kernels", bench_kernels),
]


def main() -> None:
    all_checks = []
    t_start = time.time()
    for title, mod in MODULES:
        print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")
        t0 = time.time()
        results = mod.run(verbose=True)
        checks = mod.checks(results)
        all_checks.extend(checks)
        for c in checks:
            print(c.row())
        print(f"  ({time.time() - t0:.1f}s)")

    print(f"\n{'=' * 72}\nRoofline (dry-run table)\n{'=' * 72}")
    bench_roofline.run(verbose=True)

    n_fail = sum(1 for c in all_checks if not c.ok)
    print(f"\n{'=' * 72}")
    print(f"SUMMARY: {len(all_checks) - n_fail}/{len(all_checks)} paper-claim "
          f"validations passed ({time.time() - t_start:.1f}s)")
    if n_fail:
        for c in all_checks:
            if not c.ok:
                print(c.row())
        sys.exit(1)


if __name__ == "__main__":
    main()
