"""Benchmark harness front door: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (DESIGN.md §7):

  bench_mlp       Fig. 7 / Fig. 8   (MLP cases, both systems)
  bench_lstm      Fig. 10 / Fig. 11 (LSTM n_h sweep, cases)
  bench_cnn       Fig. 13 / Fig. 14 (CNN-F/M/S, 8-core pipeline)
  bench_pipeline  §VII-IX           (executable multi-core schedules vs the
                                     cost model: measured-vs-predicted)
  bench_coupling  §VII-B            (tight vs loose, analytical + lowered)
  bench_accuracy  §III-C            (AIMC output fidelity vs digital)
  bench_kernels   kernels/          (Pallas v2 vs oracle + HBM/VMEM ledgers)
  bench_serving   runtime/engine    (continuous batching vs static batch:
                                     tok/s + latency percentiles on traces)
  bench_server    runtime/server    (multi-tenant multi-model serving: one
                                     crossbar pool, per-tenant SLOs/quotas)
  bench_placement core/placement    (auto-placement: per-layer sums vs
                                     evaluate/schedule at ratio 1.000,
                                     measured-vs-modeled roofline fit)
  bench_roofline  §Roofline         (dry-run table; run dryrun first)

``--json PATH`` writes machine-readable results — per-case wall-clock,
modeled latency, and check pass/fail — so the perf trajectory is tracked
across PRs (``make bench-json`` -> BENCH_kernels.json). ``--only NAME``
restricts to one module (the CI perf-smoke runs ``--only kernels``).

Exit code 1 if any paper-claim validation fails.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks import (bench_accuracy, bench_cnn, bench_coupling,
                        bench_kernels, bench_lstm, bench_mlp,
                        bench_pipeline, bench_placement, bench_roofline,
                        bench_server, bench_serving)

MODULES = [
    ("mlp", "MLP (paper Fig. 7/8)", bench_mlp),
    ("lstm", "LSTM (paper Fig. 10/11)", bench_lstm),
    ("cnn", "CNN (paper Fig. 13/14)", bench_cnn),
    ("pipeline", "Multi-core schedules (measured vs predicted)",
     bench_pipeline),
    ("coupling", "Coupling (paper §VII-B)", bench_coupling),
    ("accuracy", "Fidelity (paper §III-C)", bench_accuracy),
    ("kernels", "Pallas kernels", bench_kernels),
    ("serving", "Continuous-batching serving engine (static vs engine)",
     bench_serving),
    ("server", "Multi-tenant model server (tenant quotas over one pool)",
     bench_server),
    ("placement", "Auto-placement (placer sums vs model + roofline fit)",
     bench_placement),
]


def _jsonable(obj):
    """Best-effort JSON view of a module's results dict: numpy scalars ->
    Python, arrays/objects that don't serialize are dropped."""
    if isinstance(obj, dict):
        out = {}
        for k, v in obj.items():
            v = _jsonable(v)
            if v is not None:
                out[str(k)] = v
        return out
    if isinstance(obj, (list, tuple)):
        return [x for x in (_jsonable(v) for v in obj) if x is not None]
    if isinstance(obj, (str, bool, int, float)) or obj is None:
        return obj
    if hasattr(obj, "item"):                 # numpy/jax scalar
        try:
            v = obj.item()
        except (TypeError, ValueError):
            return None
        return v if isinstance(v, (str, bool, int, float)) else None
    return None


def write_report(path: str, report: dict, complete: bool) -> bool:
    """Write the JSON artifact — unless the run is PARTIAL (a sub-bench
    crashed, or ``--only`` restricted the module set) and a COMPLETE
    artifact already exists at ``path``.

    BENCH_all.json is the cross-PR perf-trajectory record: clobbering it
    with a partial run would silently erase the last complete baseline. A
    complete run that merely has failing CHECKS still writes — all its data
    is present and the exit code carries the failure (the documented
    pre-existing CNN top-1 failure must not wedge the artifact). Partial
    runs stamp ``"partial": true`` into the payload, so an existing
    partial artifact never blocks a refresh (artifacts written before this
    stamp existed are presumed complete). Returns whether the file was
    written."""
    report = dict(report, partial=not complete)
    if not complete and os.path.exists(path):
        try:
            with open(path) as f:
                prev_complete = not json.load(f).get("partial", False)
        except (OSError, ValueError):
            prev_complete = False              # unreadable: nothing to protect
        if prev_complete:
            print(f"\nNOT writing {path}: this run is partial (crashed "
                  f"sub-bench or --only) and a complete artifact exists "
                  f"(refusing to overwrite the last complete baseline)")
            return False
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\nwrote {path}")
    return True


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("--json", metavar="PATH",
                    help="write per-case results + check pass/fail as JSON")
    ap.add_argument("--only", metavar="NAME",
                    choices=[k for k, *_ in MODULES],
                    help="run a single benchmark module")
    ap.add_argument("--mesh", metavar="SPEC", default=None,
                    help="also bench the SHARDED serving engine on this "
                         "mesh (data:D,model:M); forces the host-platform "
                         "device count as needed (must precede first "
                         "backend use)")
    args = ap.parse_args(argv)
    if args.mesh:
        from repro.launch.serve import force_host_device_count
        force_host_device_count(args.mesh)

    all_checks = []
    report = {"modules": {}}
    errored = []
    t_start = time.time()
    selected = [m for m in MODULES if args.only in (None, m[0])]
    for key, title, mod in selected:
        print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")
        t0 = time.time()
        try:
            if key == "serving" and args.mesh:
                results = mod.run(verbose=True, mesh_arg=args.mesh)
            else:
                results = mod.run(verbose=True)
            checks = mod.checks(results)
        except Exception as e:             # a crashed sub-bench must not
            elapsed = time.time() - t0     # silently vanish from the report
            print(f"  ERROR: {type(e).__name__}: {e}")
            errored.append(key)
            report["modules"][key] = {"title": title, "elapsed_s": elapsed,
                                      "error": f"{type(e).__name__}: {e}"}
            continue
        all_checks.extend(checks)
        for c in checks:
            print(c.row())
        elapsed = time.time() - t0
        print(f"  ({elapsed:.1f}s)")
        report["modules"][key] = {
            "title": title,
            "elapsed_s": elapsed,
            "results": _jsonable(results),
            "checks": [{"name": c.name, "measured": c.measured,
                        "target": c.target, "rtol": c.rtol, "ok": c.ok}
                       for c in checks],
        }

    if args.only is None:
        print(f"\n{'=' * 72}\nRoofline (dry-run table)\n{'=' * 72}")
        bench_roofline.run(verbose=True)

    n_fail = sum(1 for c in all_checks if not c.ok)
    report["summary"] = {"passed": len(all_checks) - n_fail,
                         "total": len(all_checks),
                         "errored_modules": errored,
                         "elapsed_s": time.time() - t_start}
    if args.json:
        # an --only run is partial by construction: it must not clobber a
        # complete multi-module baseline
        write_report(args.json, report,
                     complete=not errored and args.only is None)

    print(f"\n{'=' * 72}")
    print(f"SUMMARY: {len(all_checks) - n_fail}/{len(all_checks)} paper-claim "
          f"validations passed ({time.time() - t_start:.1f}s)"
          + (f"; {len(errored)} module(s) ERRORED: {', '.join(errored)}"
             if errored else ""))
    if n_fail or errored:
        for c in all_checks:
            if not c.ok:
                print(c.row())
        sys.exit(1)


if __name__ == "__main__":
    main()
