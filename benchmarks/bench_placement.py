"""Auto-placement: predicted-vs-measured for the cost-model placer.

The placer (`core.placement`, DESIGN.md §16) decides analog vs digital per
layer from per-layer one-stage workloads priced by `costmodel.evaluate`.
That decomposition is only trustworthy if (a) the per-layer sums agree
EXACTLY with the monolithic model on the combined split workload, (b) the
analog side agrees EXACTLY with the independent `core.schedule` pricing of
the program the plan actually builds, and (c) the modeled digital times
RANK real layers correctly — checked by measuring per-layer digital MVM
wallclock on this host and fitting the affine `PlacementRoofline`
(measured = t_fixed + scale * modeled, the `OverlapRoofline` idiom), then
gating the per-layer relative residuals. The analog side has no silicon
under it, so it is consistency-gated (a)+(b) only — the same
modeled-latency bar the paper's own Table I numbers live on.

Gates:
  * per-layer sum / evaluate(split_workload) == 1.000 for all-digital,
    the chosen split, and all-analog (exact-by-construction; rtol 1%)
  * analog per-layer sum / CoreSchedule.from_program modeled latency
    == 1.000 on the chosen plan's program (rtol 1%)
  * affine roofline fit over measured digital per-layer wallclock:
    every relative residual <= 0.75 for layers above dispatch scale
    (< 50us measured is recorded but ungated — see the inline note)
  * predicted latency is monotone non-increasing in the tile budget
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Check, fmt_t, table
from repro.configs import get_arch
from repro.core.aimc import AimcConfig
from repro.core.costmodel import (CALIB, HIGH_POWER, digital_mvm_stage,
                                  evaluate, split_workload)
from repro.core.placement import (PlacementRoofline, layer_costs,
                                  plan_placement)
from repro.core.program import MappingPlan, program_model
from repro.core.schedule import CoreSchedule

# synthetic digital-measurement layer set: enough size spread for the
# affine fit to see the modeled time, big enough that one apply is not
# pure dispatch overhead
MEASURE_SHAPES = [(256, 256), (512, 512), (1024, 1024),
                  (1024, 4096), (2048, 2048)]
BUDGETS = (1, 2, 3, 4, 6, 8, 0)   # 0 = uncapped


def _wallclock(fn, *args, reps: int = 20) -> float:
    jax.block_until_ready(fn(*args))          # compile outside the timing
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(verbose: bool = True) -> dict:
    out: dict = {}
    spec = get_arch("granite-8b")
    cfg_model = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg_model)
    acfg = AimcConfig(impl="ref", adc_alpha=0.5, tile_rows=64)

    # ---- gate (a): per-layer sums == evaluate() on the split workload ----
    res = plan_placement(params, MappingPlan(), acfg, tiles_per_context=None,
                         n_contexts=1)
    layers = [(c.path, c.k, c.n, c.instances) for c in res.costs]
    all_paths = tuple(c.path for c in res.costs)
    rows, eval_ratios = [], []
    for name, analog in [("all_digital", ()), ("chosen", res.analog),
                         ("all_analog", all_paths)]:
        wl = split_workload(name, layers, analog, tile_rows=acfg.tile_rows)
        t_eval = evaluate(wl, HIGH_POWER, CALIB).time_s
        t_sum = res.predicted_for(analog)
        eval_ratios.append((name, t_sum / t_eval))
        rows.append([name, len(analog), fmt_t(t_sum), fmt_t(t_eval),
                     f"{t_sum / t_eval:.4f}"])
    out["eval_ratios"] = eval_ratios
    if verbose:
        print(table("placer per-layer sums vs costmodel.evaluate "
                    "(one token vector)",
                    ["split", "analog", "sum", "evaluate", "ratio"], rows))
        print()

    # ---- gate (b): analog sum == schedule pricing of the real program ----
    prog = program_model(params, res.plan, acfg, jax.random.PRNGKey(2))
    sched = CoreSchedule.from_program(prog)
    t_sched = sched.modeled_latency(HIGH_POWER, CALIB)
    analog_set = set(res.analog)
    t_analog = sum(c.t_analog for c in res.costs if c.path in analog_set)
    out["sched_ratio"] = t_analog / t_sched
    if verbose:
        print(f"  analog per-layer sum {fmt_t(t_analog)} vs "
              f"CoreSchedule.from_program {fmt_t(t_sched)} "
              f"(ratio {out['sched_ratio']:.4f})")
        print()

    # ---- budget sweep: predicted latency monotone in the budget ----------
    rows, sweep = [], []
    for b in BUDGETS:
        r = plan_placement(params, MappingPlan(), acfg,
                           tiles_per_context=b or None, n_contexts=1)
        sweep.append((b, r.predicted_s))
        rows.append([b or "inf", len(r.analog), f"{r.overflow}",
                     fmt_t(r.predicted_s),
                     f"{r.predicted_digital_s / r.predicted_s:.2f}x"])
    capped = [t for _, t in sweep[:-1]]   # BUDGETS ends with uncapped
    out["budget_sweep"] = sweep
    out["monotone"] = all(a >= b - 1e-15 for a, b in zip(capped, capped[1:]))
    out["dominates_digital"] = all(
        t <= res.predicted_digital_s + 1e-15 for _, t in sweep)
    if verbose:
        print(table("budget sweep (predicted latency must not worsen with "
                    "more budget)",
                    ["budget", "analog", "overflow", "predicted",
                     "vs digital"], rows))
        print()

    # ---- gate (c): measured digital wallclock vs modeled (roofline) ------
    modeled, measured, rows = [], [], []
    for k, n in MEASURE_SHAPES:
        w = jax.random.normal(jax.random.PRNGKey(hash((k, n)) % 2**31),
                              (k, n), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, k), jnp.float32)
        fwd = jax.jit(lambda v, w=w: v @ w)
        t_meas = _wallclock(fwd, x)
        wl_t = evaluate(
            split_workload(f"dig_{k}x{n}", [(f"m{k}x{n}", k, n, 1)], (),
                           tile_rows=acfg.tile_rows),
            HIGH_POWER, CALIB).time_s
        modeled.append(wl_t)
        measured.append(t_meas)
    fit = PlacementRoofline.fit(modeled, measured)
    resid = fit.residuals(modeled, measured)
    # layers whose measured time sits at dispatch scale (< 50us) are
    # recorded but NOT gated: a ~10-20us wallclock swings 2x with run
    # context (JIT cache/CPU state), and the affine fit's fixed term is
    # anchored by the ms-scale layers — gating the noise would make the
    # whole suite flaky. Logged per the no-silent-caps rule.
    gated = [r for tw, r in zip(measured, resid) if tw >= 50e-6]
    dropped = [f"{k}x{n}" for (k, n), tw in zip(MEASURE_SHAPES, measured)
               if tw < 50e-6]
    for (k, n), tm, tw, r in zip(MEASURE_SHAPES, modeled, measured, resid):
        rows.append([f"{k}x{n}", fmt_t(tm), fmt_t(tw),
                     fmt_t(fit.predict_s(tm)), f"{r:.2f}"])
    out["roofline"] = {"t_fixed_s": fit.t_fixed_s, "scale": fit.scale,
                       "residuals": resid, "gated_residuals": gated,
                       "ungated_layers": dropped}
    if verbose and dropped:
        print(f"  NOT gated (dispatch-scale, < 50us measured): "
              f"{', '.join(dropped)}")
    if verbose:
        print(table(
            f"digital per-layer wallclock vs modeled "
            f"(fit: {fit.t_fixed_s * 1e6:.1f}us + {fit.scale:.2f} x "
            f"modeled)",
            ["layer", "modeled", "measured", "fit-predicted",
             "rel-residual"], rows))
        print()
    return out


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    worst_eval = max(abs(r - 1.0) for _, r in results["eval_ratios"])
    roof = results["roofline"]
    worst_resid = max(roof.get("gated_residuals") or roof["residuals"])
    return [
        Check("placer per-layer sums == evaluate(split_workload)",
              1.0 + worst_eval, 1.0, rtol=0.01),
        Check("placer analog sum == schedule-modeled program latency",
              results["sched_ratio"], 1.0, rtol=0.01),
        Check("predicted latency monotone non-worsening in budget",
              1.0 if results["monotone"] else 0.0, 1.0, rtol=0.01),
        Check("chosen split never worse than all-digital",
              1.0 if results["dominates_digital"] else 0.0, 1.0, rtol=0.01),
        Check("measured digital wallclock within roofline fit "
              "(max rel residual <= 0.75)",
              1.0 if worst_resid <= 0.75 else 0.0, 1.0, rtol=0.01),
    ]


if __name__ == "__main__":
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH",
                    help="write results + checks as JSON")
    args = ap.parse_args()
    res = run()
    cs = checks(res)
    for c in cs:
        print(c.row())
    if args.json:
        payload = {"results": {k: v for k, v in res.items()},
                   "checks": [{"name": c.name, "measured": c.measured,
                               "target": c.target, "ok": c.ok} for c in cs]}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")
    sys.exit(0 if all(c.ok for c in cs) else 1)
