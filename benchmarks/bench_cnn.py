"""Paper Fig. 13 + Fig. 14 — CNN exploration (§IX).

CNN-F/M/S (Chatfield et al. [42]) on the 8-core MPSoC with fine-grained
pipelining; convolutional layers AIMC-mapped (im2col columns, [43]), dense
layers digital. Checks (§IX headline claims):
  * CNN-S speedup up to 20.5x / energy 20.8x (high-power),
  * CNN memory-intensity improvement ~3.7x (CNN-S, high-power),
  * total inference time larger than MLP/LSTM (multiple kernel passes).
"""

from __future__ import annotations

from benchmarks.common import Check, fmt_e, fmt_t, table
from repro.core.costmodel import HIGH_POWER, LOW_POWER, evaluate, speedup
from repro.core.workloads import cnn_workloads


def run(verbose: bool = True) -> dict:
    results = {}
    for sysc in (HIGH_POWER, LOW_POWER):
        res = {}
        for v in "FMS":
            w = cnn_workloads(v)
            res[v] = {c: evaluate(w[c], sysc) for c in ("dig", "ana")}
        results[sysc.name] = res
        if verbose:
            rows = []
            for v in "FMS":
                dig, ana = res[v]["dig"], res[v]["ana"]
                s, e = speedup(dig, ana)
                mi = dig.dram_bytes / max(ana.dram_bytes, 1.0)
                rows.append([f"CNN-{v}", fmt_t(dig.time_s), fmt_t(ana.time_s),
                             f"{s:.1f}x", f"{e:.1f}x", f"{mi:.1f}x"])
            print(table(f"CNN — {sysc.name} system, 8 cores (Fig. 13)",
                        ["net", "digital t/inf", "analog t/inf", "speedup",
                         "energy gain", "mem-int gain"], rows))
            print()
    if verbose:
        # Fig. 14 flavour: per-stage (core) busy times for CNN-S analog
        ana = results["high-power"]["S"]["ana"]
        stage_rows = [[f"core{i}", fmt_t(t),
                       f"{t / max(ana.stage_times):.0%}"]
                      for i, t in enumerate(ana.stage_times)]
        print(table("CNN-S analog per-core busy time (Fig. 14 analogue)",
                    ["core", "busy", "of max (pipeline stage)"], stage_rows))
        print()
    return results


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    hp = results["high-power"]
    sS, eS = speedup(hp["S"]["dig"], hp["S"]["ana"])
    mi = hp["S"]["dig"].dram_bytes / max(hp["S"]["ana"].dram_bytes, 1.0)
    return [
        Check("CNN-S speedup (high-power)", sS, 20.5),
        Check("CNN-S energy gain (high-power)", eS, 20.8),
        # paper Fig. 13 reports 3.7x LLCMPI improvement from gem5's real cache
        # simulation; our analytical cache model reproduces the direction and
        # magnitude class (>=1.5x DRAM-traffic reduction), not the exact
        # figure — see EXPERIMENTS.md §Paper-calibration.
        Check("CNN-S memory-traffic improvement >= 1.5x",
              1.0 if mi >= 1.5 else 0.0, 1.0, rtol=0.01),
    ]


if __name__ == "__main__":
    res = run()
    for c in checks(res):
        print(c.row())
