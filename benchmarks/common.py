"""Shared table/validation helpers for the benchmark harness."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Check:
    """One validation of a paper claim."""
    name: str
    measured: float
    target: float
    rtol: float = 0.15           # the paper reports 3 significant digits at best

    @property
    def ok(self) -> bool:
        return abs(self.measured - self.target) <= self.rtol * abs(self.target)

    def row(self) -> str:
        flag = "PASS" if self.ok else "FAIL"
        return (f"  [{flag}] {self.name:52s} measured={self.measured:8.2f}  "
                f"paper={self.target:8.2f}  (rtol {self.rtol:.0%})")


def table(title: str, header: list[str], rows: list[list]) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(header)]
    out = [f"== {title} =="]
    out.append("  ".join(str(h).ljust(w) for h, w in zip(header, widths)))
    out.append("  ".join("-" * w for w in widths))
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)


def fmt_t(seconds: float) -> str:
    if seconds < 1e-3:
        return f"{seconds * 1e6:.1f}us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.2f}ms"
    return f"{seconds:.3f}s"


def fmt_e(joules: float) -> str:
    if joules < 1e-3:
        return f"{joules * 1e6:.1f}uJ"
    if joules < 1.0:
        return f"{joules * 1e3:.2f}mJ"
    return f"{joules:.3f}J"
