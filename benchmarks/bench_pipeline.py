"""Executable multi-core mappings vs the analytical cost model.

The consistency layer the scheduler enables (methodology of Sun et al.,
"Analog or Digital In-memory Computing? Benchmarking through Quantitative
Modeling": a model is only trustworthy once checked against execution).
Every paper multi-core case — MLP cases 1/3/4, LSTM cases 2/3/4, the
position-pipelined CNN — runs twice:

  1. EXECUTED through `core.schedule.CoreSchedule` (real JAX math on this
     host; interleaved core execution), measuring wallclock and verifying
     the multi-core outputs are numerically identical to the single-core
     programmed path.
  2. PREDICTED by `costmodel.evaluate()` on the matching `Workload` IR,
     and independently by the schedule's own per-core ledgers priced
     through the shared `costmodel.aimc_mvm_time` accounting.

Checks: (a) outputs bit-equal across core counts; (b) schedule-modeled
latency == workload-evaluated latency (the two descriptions of one mapping
can never drift); (c) per-core dequeue ledgers partition the single-core
program totals; (d) the measured CNN pipeline speedup (sum-of-stages /
max-stage over real per-stage wallclock) tracks the predicted law within
the host-vs-model tolerance.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Check, fmt_t, table
from repro.core import isa
from repro.core.aimc import AimcConfig
from repro.core.costmodel import HIGH_POWER, evaluate
from repro.core.schedule import (cnn_schedule, lstm_schedule, mlp_schedule,
                                 pipeline_run, pipelined_latency,
                                 sequential_latency)
from repro.core.workloads import cnn_workloads, lstm_workloads, mlp_workloads
from repro.models import paper_nets as pn

N_MLP = 1024
NH_LSTM = 600          # gate-sliceable (nh % 4 == 0), mid paper sweep
CNN_VARIANT = "F"


def _wallclock(fn, *args, reps: int = 5) -> float:
    jax.block_until_ready(fn(*args))          # compile outside the timing
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _max_diff(a, b) -> float:
    return float(jnp.max(jnp.abs(a - b)))


def run(verbose: bool = True) -> dict:
    out: dict = {"consistency": [], "equal": [], "ledger": []}

    # ---- MLP cases 1/3/4 (Fig. 6) -------------------------------------------
    params = pn.mlp_init(jax.random.PRNGKey(0), n=N_MLP)
    cfg = AimcConfig(tile_rows=N_MLP, tile_cols=N_MLP)
    prog = pn.mlp_program(params, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, N_MLP))
    wl = mlp_workloads(N_MLP)
    rows, y_ref, t_ref = [], None, None
    for cores, case in ((1, "ana_case1"), (2, "ana_case3"), (4, "ana_case4")):
        sched = mlp_schedule(prog, cores)
        fwd = jax.jit(lambda v, s=sched: pn.mlp_forward_multicore(
            params, v, cfg, schedule=s)[0])
        t_meas = _wallclock(fwd, x)
        y = fwd(x)
        pred_wl = evaluate(wl[case], HIGH_POWER).time_s
        pred_sched = sched.modeled_latency(HIGH_POWER)
        if cores == 1:
            y_ref, t_ref, pred_ref = y, t_meas, pred_wl
        out["consistency"].append((f"mlp_{cores}c", pred_sched / pred_wl))
        out["equal"].append((f"mlp_{cores}c", _max_diff(y, y_ref)))
        out["ledger"].append(
            (f"mlp_{cores}c", sched.ledger_totals().dequeue,
             prog.mvm_counts().dequeue))
        rows.append([case, cores, fmt_t(t_meas), f"{t_meas / t_ref:.2f}x",
                     fmt_t(pred_wl), f"{pred_wl / pred_ref:.2f}x",
                     f"{pred_sched / pred_wl:.3f}",
                     f"{_max_diff(y, y_ref):.1e}"])
    # kernel-v2 fused-epilogue twins: the relu rides the dequeue loop in
    # BOTH descriptions (Op.epilogue / Shard.epilogue_fn), so the two
    # latencies must still agree exactly.
    for cores, case in ((1, "ana_case1_fused"), (2, "ana_case3_fused")):
        sched = mlp_schedule(prog, cores, fuse_epilogue=True)
        pred_wl = evaluate(wl[case], HIGH_POWER).time_s
        pred_sched = sched.modeled_latency(HIGH_POWER)
        out["consistency"].append((f"mlp_{cores}c_fused", pred_sched / pred_wl))
        rows.append([case, cores, "-", "-", fmt_t(pred_wl),
                     f"{pred_wl / pred_ref:.2f}x",
                     f"{pred_sched / pred_wl:.3f}", "-"])
    if verbose:
        print(table(
            f"MLP ({N_MLP},{N_MLP}) multi-core: executed vs predicted",
            ["case", "cores", "measured", "ratio", "predicted", "ratio",
             "sched/wl", "max|y-y_1c|"], rows))
        print()

    # ---- LSTM cases 2/3/4 (Table II-B) ---------------------------------------
    lp = pn.lstm_init(jax.random.PRNGKey(2), NH_LSTM)
    lcfg = AimcConfig(tile_rows=NH_LSTM + 100, tile_cols=4 * NH_LSTM)
    lprog = pn.lstm_program(lp, lcfg)
    xs = jax.random.normal(jax.random.PRNGKey(3), (4, 1, 50))
    lwl = lstm_workloads(NH_LSTM)
    rows, y_ref, t_ref = [], None, None
    for cores, case in ((1, "ana_case2"), (2, "ana_case3"), (5, "ana_case4")):
        sched = lstm_schedule(lprog, cores, NH_LSTM)
        fwd = jax.jit(lambda v, s=sched: pn.lstm_forward_multicore(
            lp, v, NH_LSTM, lcfg, schedule=s)[0])
        t_meas = _wallclock(fwd, xs, reps=3) / xs.shape[0]   # per step
        y = fwd(xs)
        pred_wl = evaluate(lwl[case], HIGH_POWER).time_s
        pred_sched = sched.modeled_latency(HIGH_POWER)
        if cores == 1:
            y_ref, t_ref, pred_ref = y, t_meas, pred_wl
        out["consistency"].append((f"lstm_{cores}c", pred_sched / pred_wl))
        out["equal"].append((f"lstm_{cores}c", _max_diff(y, y_ref)))
        out["ledger"].append(
            (f"lstm_{cores}c", sched.ledger_totals().dequeue,
             lprog.mvm_counts().dequeue))
        rows.append([case, cores, fmt_t(t_meas), f"{t_meas / t_ref:.2f}x",
                     fmt_t(pred_wl), f"{pred_wl / pred_ref:.2f}x",
                     f"{pred_sched / pred_wl:.3f}",
                     f"{_max_diff(y, y_ref):.1e}"])
    if verbose:
        print(table(
            f"LSTM n_h={NH_LSTM} multi-core: executed vs predicted "
            "(per sequence step)",
            ["case", "cores", "measured", "ratio", "predicted", "ratio",
             "sched/wl", "max|y-y_1c|"], rows))
        print()

    # ---- CNN position-level pipeline (§IX-A) ---------------------------------
    cp = pn.cnn_init(jax.random.PRNGKey(4), CNN_VARIANT)
    ccfg = AimcConfig(tile_rows=1024, tile_cols=4096)
    cprog = pn.cnn_program(cp, CNN_VARIANT, ccfg)
    csched = cnn_schedule(cprog, pn.CNN_SPECS[CNN_VARIANT])
    xi = jax.random.normal(jax.random.PRNGKey(5), (1, 224, 224, 3))
    stages = [jax.jit(f) for f in pn.cnn_pipeline_stages(
        cp, CNN_VARIANT, ccfg, csched)]
    _ = pipeline_run(stages, [xi])                       # compile
    outs, stage_times = pipeline_run(stages, [xi, xi])
    y_pipe = outs[-1]
    y_1c, _ = pn.cnn_forward_multicore(cp, xi, CNN_VARIANT, ccfg,
                                       schedule=csched)
    meas_seq = sum(stage_times)
    meas_pipe = max(stage_times)
    res = evaluate(cnn_workloads(CNN_VARIANT)["ana"], HIGH_POWER)
    n_conv = len(pn.CNN_SPECS[CNN_VARIANT])
    pred_conv_max = max(res.stage_times[:n_conv])
    sched_times = csched.phase_times(HIGH_POWER)
    sched_pipe = pipelined_latency(sched_times)
    pred_speedup = sum(res.stage_times) / max(res.stage_times)
    meas_speedup = meas_seq / meas_pipe
    out["consistency"].append(("cnn_conv_max", sched_pipe / pred_conv_max))
    out["equal"].append(("cnn_pipe", _max_diff(y_pipe, y_1c)))
    # every conv fires hw^2 position MVMs: the ledger must equal the
    # per-matrix counts scaled by the position counts, summed over cores
    want = sum(isa.mvm_counts(cprog[sh.name].k, cprog[sh.name].n,
                              ccfg.tile_rows).dequeue * sh.count
               for sh in csched.shards)
    out["ledger"].append(("cnn_pipe", csched.ledger_totals().dequeue, want))
    out["cnn"] = {"meas_seq": meas_seq, "meas_pipe": meas_pipe,
                  "pred_seq": sum(res.stage_times),
                  "pred_pipe": max(res.stage_times),
                  "meas_speedup": meas_speedup, "pred_speedup": pred_speedup}
    if verbose:
        rows = [["sequential (sum of stages)", fmt_t(meas_seq),
                 fmt_t(sum(res.stage_times)), "-"],
                ["pipelined (max stage)", fmt_t(meas_pipe),
                 fmt_t(max(res.stage_times)), "-"],
                ["pipeline speedup", f"{meas_speedup:.2f}x",
                 f"{pred_speedup:.2f}x",
                 f"{meas_speedup / pred_speedup:.2f}"],
                ["conv max stage (sched vs wl)", fmt_t(sched_pipe),
                 fmt_t(pred_conv_max),
                 f"{sched_pipe / pred_conv_max:.3f}"]]
        print(table(
            f"CNN-{CNN_VARIANT} position-level pipeline: measured per-stage "
            "wallclock vs cost model",
            ["quantity", "measured", "predicted", "ratio"], rows))
        print(f"  per-stage wallclock: "
              + "  ".join(f"s{i}={fmt_t(t)}"
                          for i, t in enumerate(stage_times)))
        print(f"  max|y_pipe - y_1core| = {_max_diff(y_pipe, y_1c):.1e}")
        print()
    return out


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    out = []
    for name, ratio in results["consistency"]:
        out.append(Check(f"sched-modeled == cost-model latency [{name}]",
                         ratio, 1.0, rtol=0.01))
    # MLP/LSTM column-split cases are bit-exact (0.0); the CNN pipeline
    # compares a per-stage-jitted chain against the eager single-core run,
    # where XLA fusion reassociates float accumulation at ~1e-8 — far below
    # the int8 quantization step, and no schedule-induced difference.
    worst = max(d for _n, d in results["equal"])
    out.append(Check("multi-core outputs == single-core (max |diff|)",
                     1.0 + worst, 1.0, rtol=1e-6))
    for name, got, want in results["ledger"]:
        out.append(Check(f"per-core dequeue ledgers partition totals "
                         f"[{name}]", got / max(want, 1), 1.0, rtol=0))
    cnn = results["cnn"]
    out.append(Check("CNN measured pipeline speedup vs predicted law",
                     cnn["meas_speedup"] / cnn["pred_speedup"], 1.0,
                     rtol=0.75))
    return out


if __name__ == "__main__":
    res = run()
    for c in checks(res):
        print(c.row())
