"""Multi-tenant server benchmark: co-resident models, one crossbar pool.

The deployment story past a single engine (DESIGN.md §12): a granite-8b
programmed on the shared AIMC tile pool and an xlstm-350m running digital
are kept resident in ONE process, and a mixed-tenant request stream is
routed by tenant over them (`runtime.server.ModelServer`). Weights stay
stationary for the whole run — CM_INITIALIZE happened once per model at
build, the serving region is queue/process/dequeue only.

Measured:
  * mixed Poisson trace over three tenants (premium/standard on granite,
    weights 2:1; batch on xlstm): per-tenant tok/s, p50/p99 TTFT and
    per-output-token latency, and that EVERY tenant with requests makes
    progress;
  * a saturated synchronized burst on the shared granite slots: each
    tenant's decode-slot share must track its weight (Jain's index over
    weight-normalized shares, and the min share/entitlement ratio — the
    no-starvation bar);
  * per-tenant CM_* ledger reconciliation: summed per-tenant books close
    EXACTLY against each programmed model's ``program.mvm_counts()``;
  * shared-pool crossbar-capacity utilization and per-engine compile
    counts (shape stability across interleaved multi-model serving);
  * the same mixed trace through a ``decode_chunk=4`` server (DESIGN.md
    §13): per-request tokens must be chunk-invariant for every tenant and
    the per-tenant books must still close when quota accounting lands on
    chunk boundaries instead of single steps.

``--json BENCH_server.json`` is the machine-readable artifact
(``benchmarks.run --json`` includes this module; ``make bench-json``).
"""

from __future__ import annotations

import time

from benchmarks.common import Check, table
from repro.configs import get_arch
from repro.runtime.batcher import Request
from repro.runtime.server import ModelSpec, build_server
from repro.runtime.tenancy import (TenantPolicy, TenantRequest, jains_index,
                                   mixed_poisson_trace)

N_REQ = 18
RATE = 150.0                  # req/s: arrivals overlap decode at smoke scale
PROMPT = (4, 10)
MAX_NEW = (2, 10)
PAD = 10
N_SLOTS = 3                   # 3 slots + weights 2:1 -> steady state (2, 1)

SPECS = [ModelSpec("granite_8b", "granite-8b", "aimc"),
         ModelSpec("xlstm_350m", "xlstm-350m", "digital")]
TENANTS = [TenantPolicy("premium", "granite_8b", weight=2.0,
                        slo_ttft_s=0.5, slo_tpot_s=0.25),
           TenantPolicy("standard", "granite_8b", weight=1.0,
                        admission="sjf"),
           TenantPolicy("batch", "xlstm_350m", weight=1.0)]


def _build(verbose: bool):
    t0 = time.time()
    server = build_server(SPECS, TENANTS, smoke=True, n_slots=N_SLOTS,
                          prompt_pad=PAD, max_seq=PAD + MAX_NEW[1] + 2)
    server.warmup()
    t_build = time.time() - t0
    if verbose:
        print(f"built + co-programmed + warmed {len(SPECS)} models in "
              f"{t_build:.1f}s; {server.pool.summary()}")
    return server, t_build


def _mixed_case(server, verbose: bool) -> dict:
    """Interleaved Poisson traffic across all three tenants."""
    vocab_of = {s.name: get_arch(s.arch).smoke_cfg.vocab for s in SPECS}
    trace = mixed_poisson_trace(TENANTS, N_REQ, RATE, vocab_of=vocab_of,
                                seed=7, prompt_len=PROMPT, max_new=MAX_NEW)
    report = server.serve(trace)
    stats = report.tenant_stats()
    recon = server.reconcile(report)
    case = {
        "trace": f"poisson:{RATE:.0f} n={N_REQ} prompt={PROMPT} "
                 f"max_new={MAX_NEW}",
        "makespan_s": report.makespan_s,
        "tenants": {name: {
            "model": st.model, "n_requests": st.n_requests,
            "generated_tokens": st.generated_tokens, "tok_s": st.tok_s,
            "p50_ttft_s": st.p50_ttft_s, "p99_ttft_s": st.p99_ttft_s,
            "p50_tpot_s": st.p50_tpot_s, "p99_tpot_s": st.p99_tpot_s,
            "slo_ttft_ok": st.slo_ttft_ok, "slo_tpot_ok": st.slo_tpot_ok,
        } for name, st in stats.items()},
        "all_tenants_progress": all(
            st.generated_tokens > 0 for st in stats.values()
            if st.n_requests > 0),
        "ledgers_reconcile": {m: ok for m, ok in recon.items()},
        "compile_counts": server.compile_counts(),
        "stable_shapes": all(
            c == {"prefill": 1, "insert": 1, "decode": 1}
            for c in server.compile_counts().values()),
        "pool_utilization": server.pool.utilization,
    }
    if verbose:
        rows = [[n, d["model"], d["n_requests"], d["generated_tokens"],
                 f"{d['tok_s']:.1f}", f"{d['p50_ttft_s'] * 1e3:.0f}",
                 f"{d['p99_ttft_s'] * 1e3:.0f}"]
                for n, d in sorted(case["tenants"].items())]
        print(table(f"mixed trace — {case['trace']}",
                    ["tenant", "model", "reqs", "toks", "tok/s",
                     "p50 ttft ms", "p99 ttft ms"], rows))
        print(f"  all tenants progress: {case['all_tenants_progress']}  "
              f"ledgers reconcile: {case['ledgers_reconcile']}  "
              f"shape-stable: {case['stable_shapes']}  "
              f"pool util: {case['pool_utilization'] * 100:.0f}%")
    return case


def _saturation_case(server, verbose: bool) -> dict:
    """Synchronized burst on the shared granite slots: premium (weight 2)
    and standard (weight 1) each submit more work than the slots hold, so
    the quota scheduler alone decides the decode-slot split. The run is CUT
    by a step budget while BOTH tenants still have backlog — over a fully
    completed equal backlog the whole-run shares are equal by construction;
    the quota only shows while there is contention."""
    per_tenant, max_new, p_len, step_budget = 6, 12, 6, 30
    vocab = get_arch("granite-8b").smoke_cfg.vocab
    import random
    rng = random.Random(5)
    trace = []
    for i in range(per_tenant * 2):
        trace.append(TenantRequest(
            tenant="premium" if i % 2 == 0 else "standard",
            request=Request(
                rid=1000 + i,
                prompt=tuple(rng.randint(1, vocab - 1)
                             for _ in range(p_len)),
                max_new=max_new, arrival=0.0)))
    report = server.serve(trace, max_steps=step_budget)
    shares = {}
    for name in ("premium", "standard"):
        recs = report.tenant_records(name)
        shares[name] = sum(r.decode_vectors for r in recs.values())
    total = sum(shares.values())
    wsum = sum(p.weight for p in TENANTS if p.model == "granite_8b")
    entitle = {p.name: p.weight / wsum
               for p in TENANTS if p.model == "granite_8b"}
    ratio = {n: (shares[n] / total) / entitle[n] for n in shares}
    fairness = jains_index([shares[n] / entitle[n] for n in shares])
    case = {
        "trace": f"synchronized burst, {per_tenant} reqs/tenant x "
                 f"max_new={max_new} on {N_SLOTS} granite slots, cut at "
                 f"{step_budget} decode steps (contended window)",
        "decode_slot_vectors": shares,
        "entitlement": entitle,
        "share_over_entitlement": ratio,
        "min_share_ratio": min(ratio.values()),
        "jain_weighted": fairness,
        "ledgers_reconcile": server.reconcile(report),
    }
    if verbose:
        print(table(case["trace"],
                    ["tenant", "slot-vectors", "share", "entitled",
                     "share/entitled"],
                    [[n, shares[n], f"{shares[n] / total:.2f}",
                      f"{entitle[n]:.2f}", f"{ratio[n]:.2f}"]
                     for n in sorted(shares)]))
        print(f"  Jain (weight-normalized): {fairness:.3f}  "
              f"min share/entitlement: {case['min_share_ratio']:.2f}  "
              f"ledgers: {case['ledgers_reconcile']}")
    return case


def _chunked_case(server, verbose: bool) -> dict:
    """The mixed trace again, through a server whose engines run the
    k=4 scanned-decode chunk (DESIGN.md §13). Tokens are chunk-invariant
    by construction, so every tenant's every request must decode to the
    same ids as the per-step server, and the per-tenant ledgers must still
    close when quota accounting lands on chunk boundaries."""
    t0 = time.time()
    server4 = build_server(SPECS, TENANTS, smoke=True, n_slots=N_SLOTS,
                           prompt_pad=PAD, max_seq=PAD + MAX_NEW[1] + 2,
                           decode_chunk=4)
    server4.warmup()
    t_build = time.time() - t0
    vocab_of = {s.name: get_arch(s.arch).smoke_cfg.vocab for s in SPECS}
    trace = mixed_poisson_trace(TENANTS, N_REQ, RATE, vocab_of=vocab_of,
                                seed=7, prompt_len=PROMPT, max_new=MAX_NEW)
    rep1 = server.serve(list(trace))
    rep4 = server4.serve(list(trace))
    chunk_invariant = True
    for pol in TENANTS:
        recs1 = rep1.tenant_records(pol.name)
        recs4 = rep4.tenant_records(pol.name)
        chunk_invariant = chunk_invariant and set(recs1) == set(recs4) and \
            all(recs1[rid].tokens == recs4[rid].tokens for rid in recs1)
    recon = server4.reconcile(rep4)
    # each engine compiles one decode executable per ladder length
    # {1, 2, 4}; interleaved chunked serving must not add any
    counts = server4.compile_counts()
    stable = all(c == {"prefill": 1, "insert": 1, "decode": 3}
                 for c in counts.values())
    case = {
        "decode_chunk": 4,
        "build_warmup_s": t_build,
        "tokens_chunk_invariant": chunk_invariant,
        "ledgers_reconcile": {m: ok for m, ok in recon.items()},
        "compile_counts": counts,
        "stable_shapes": stable,
    }
    if verbose:
        print(f"chunked server (k=4): tokens chunk-invariant "
              f"{chunk_invariant}  ledgers: {case['ledgers_reconcile']}  "
              f"shape-stable: {stable}")
    return case


def run(verbose: bool = True) -> dict:
    server, t_build = _build(verbose)
    return {
        "models": [{"name": s.name, "arch": s.arch, "exec": s.exec_mode}
                   for s in SPECS],
        "tenant_policies": [{"name": p.name, "model": p.model,
                             "weight": p.weight, "admission": p.admission}
                            for p in TENANTS],
        "n_slots": N_SLOTS,
        "build_warmup_s": t_build,
        "pool": server.pool.summary(),
        "mixed": _mixed_case(server, verbose),
        "saturation": _saturation_case(server, verbose),
        "chunked": _chunked_case(server, verbose),
    }


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    mixed, sat = results["mixed"], results["saturation"]
    chunked = results["chunked"]
    recon_ok = (all(ok is not False
                    for ok in mixed["ledgers_reconcile"].values())
                and all(ok is not False
                        for ok in sat["ledgers_reconcile"].values())
                and all(ok is not False
                        for ok in chunked["ledgers_reconcile"].values()))
    return [
        Check("every tenant with requests makes progress (no starvation)",
              1.0 if mixed["all_tenants_progress"] else 0.0, 1.0, rtol=0.01),
        Check("per-tenant CM_* ledgers reconcile against each program",
              1.0 if recon_ok else 0.0, 1.0, rtol=0.01),
        Check("saturated decode-slot shares track tenant weights (Jain)",
              sat["jain_weighted"], 1.0, rtol=0.10),
        Check("min tenant share/entitlement under saturation",
              sat["min_share_ratio"], 1.0, rtol=0.30),
        Check("engine shapes jit-stable across interleaved models",
              1.0 if mixed["stable_shapes"] else 0.0, 1.0, rtol=0.01),
        Check("chunked (k=4) server tokens chunk-invariant per tenant",
              1.0 if chunked["tokens_chunk_invariant"] else 0.0, 1.0,
              rtol=0.01),
        Check("chunked server shapes jit-stable (ladder pre-compiled)",
              1.0 if chunked["stable_shapes"] else 0.0, 1.0, rtol=0.01),
    ]


if __name__ == "__main__":
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH",
                    help="write results + checks as JSON")
    args = ap.parse_args()
    res = run()
    cs = checks(res)
    for c in cs:
        print(c.row())
    if args.json:
        payload = {"results": res,
                   "checks": [{"name": c.name, "measured": c.measured,
                               "target": c.target, "ok": c.ok} for c in cs]}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")
    sys.exit(0 if all(c.ok for c in cs) else 1)
