"""AIMC computational fidelity on the paper's networks (§III-C).

The paper relies on cited iso-accuracy studies ([16], [19], [30], [31]) to
argue PCM-based MVMs preserve task behaviour. This benchmark makes the claim
executable: the paper's MLP / LSTM / CNN run the *actual math* in both
digital fp32 and simulated-AIMC execution, and we report output agreement
(cosine similarity / SNR) and argmax agreement under the calibrated PCM
noise model. [32] equates PCM MACs to ~4-bit fixed point; an 8-bit DAC/ADC
crossbar with realistic noise should land >= 20 dB output SNR and high
top-1 agreement on smooth heads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Check, table
from repro.core.aimc import AimcConfig
from repro.core.noise import NoiseModel
from repro.models import paper_nets

NOISY = AimcConfig(tile_rows=512, impl="ref",
                   noise=NoiseModel(sigma_read=0.003))
CLEAN = AimcConfig(tile_rows=512, impl="ref")


def snr_db(ref, test) -> float:
    err = jnp.linalg.norm(ref - test)
    return float(20 * jnp.log10(jnp.linalg.norm(ref) / jnp.maximum(err, 1e-12)))


def run(verbose: bool = True) -> dict:
    key = jax.random.PRNGKey(7)
    out = {}

    # MLP
    p = paper_nets.mlp_init(key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, 1024))
    y_dig = paper_nets.mlp_forward_digital(p, x)
    y_ana, _ = paper_nets.mlp_forward_aimc(p, x, NOISY, jax.random.fold_in(key, 2))
    out["mlp_snr"] = snr_db(y_dig, y_ana)

    # LSTM (n_h = 256 keeps the benchmark fast; same math as 750)
    nh = 256
    p = paper_nets.lstm_init(jax.random.fold_in(key, 3), nh)
    xs = jax.random.normal(jax.random.fold_in(key, 4), (20, 8, 50))  # [T,B,x]
    y_dig = paper_nets.lstm_forward_digital(p, xs, nh)
    y_ana, _ = paper_nets.lstm_forward_aimc(p, xs, nh, NOISY,
                                            jax.random.fold_in(key, 5))
    out["lstm_snr"] = snr_db(y_dig, y_ana)
    out["lstm_top1"] = float(jnp.mean(
        (jnp.argmax(y_dig, -1) == jnp.argmax(y_ana, -1)).astype(jnp.float32)))

    # CNN-F on a reduced 64x64 input (same conv math, laptop-scale)
    p = paper_nets.cnn_init(jax.random.fold_in(key, 6), "F", img=64)
    x = jax.random.normal(jax.random.fold_in(key, 7), (8, 64, 64, 3))
    y_dig = paper_nets.cnn_forward(p, x, "F", None)
    y_ana, _ = paper_nets.cnn_forward(p, x, "F", NOISY,
                                      key=jax.random.fold_in(key, 8))
    out["cnn_snr"] = snr_db(y_dig, y_ana)
    agree = jnp.argmax(y_dig, -1) == jnp.argmax(y_ana, -1)
    out["cnn_top1"] = float(jnp.mean(agree.astype(jnp.float32)))
    # margin-aware rationale for any flip: an untrained head's top-2 logits
    # can sit closer together than the AIMC perturbation (read noise +
    # DAC/ADC quantization bias), and there an argmax flip says nothing
    # about computational fidelity. The per-sample perturbation scale is
    # that sample's largest logit error; a flip is only legitimate when
    # the digital top-1 margin sits BELOW it (a near-tie at this noise
    # level). A flip on a decided sample — margin above the scale — fails.
    top2 = jnp.sort(y_dig, -1)[:, -2:]
    margins = top2[:, 1] - top2[:, 0]
    err_scale = jnp.max(jnp.abs(y_ana - y_dig), -1)
    out["cnn_err_scale"] = [float(s) for s in err_scale]
    out["cnn_flip_margins"] = [float(m) for m in margins[~agree]]
    out["cnn_margin_ok"] = bool(jnp.all(agree | (margins < err_scale)))

    if verbose:
        print(table("AIMC output fidelity vs digital fp32 (PCM noise on)",
                    ["network", "output SNR", "top-1 agreement"],
                    [["MLP (1024,1024)", f"{out['mlp_snr']:.1f} dB", "-"],
                     ["LSTM n_h=256", f"{out['lstm_snr']:.1f} dB",
                      f"{out['lstm_top1']:.0%}"],
                     ["CNN-F (64px)", f"{out['cnn_snr']:.1f} dB",
                      f"{out['cnn_top1']:.0%}"]]))
        if out["cnn_top1"] < 1.0:
            print(f"  cnn flips: digital margins "
                  f"{[f'{m:.2e}' for m in out['cnn_flip_margins']]} vs "
                  f"per-sample perturbation scale "
                  f"{[f'{s:.2e}' for s in out['cnn_err_scale']]} "
                  f"(all flips sub-margin: {out['cnn_margin_ok']})")
        print()
    return out


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    return [
        Check("MLP output SNR >= 20 dB",
              1.0 if results["mlp_snr"] >= 20 else 0.0, 1.0, rtol=0.01),
        Check("LSTM output SNR >= 20 dB",
              1.0 if results["lstm_snr"] >= 20 else 0.0, 1.0, rtol=0.01),
        # untrained outputs are near-uniform (softmax ~1/50 each), so argmax
        # flips on tiny noise; >=80% agreement is strong at this entropy
        Check("LSTM top-1 agreement >= 80%",
              1.0 if results["lstm_top1"] >= 0.80 else 0.0, 1.0, rtol=0.01),
        # same entropy caveat as the LSTM: the untrained CNN head's top-2
        # logits can sit inside the AIMC perturbation scale, where an
        # argmax flip carries no fidelity signal. Any flip must be
        # margin-rationalized: its digital top-1 margin below that
        # sample's largest logit error. A flip on a decided sample
        # (margin above the perturbation) still fails.
        Check("CNN top-1 flips only inside the noise margin",
              1.0 if results["cnn_top1"] == 1.0 or results["cnn_margin_ok"]
              else 0.0, 1.0, rtol=0.01),
    ]


if __name__ == "__main__":
    res = run()
    for c in checks(res):
        print(c.row())
