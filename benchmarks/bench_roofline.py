"""§Roofline — the dry-run-derived roofline table (EXPERIMENTS.md §Roofline).

Reads ``experiments/dryrun/*.json`` (written by ``repro.launch.dryrun``) and
prints, per (arch x shape x mesh x exec x variant) cell:

  compute_s     HLO_FLOPs / peak_FLOPs        (while-aware, per device)
  memory_s      HLO_bytes / HBM_bw
  collective_s  collective wire bytes / ICI link bw
  dominant      the bottleneck term
  useful        MODEL_FLOPS / HLO_FLOPs
  RL%           roofline fraction: (MODEL_FLOPS/peak) / max(term)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import table

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def load(dryrun_dir: str = DRYRUN_DIR, variant: str | None = None):
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if variant is not None and r.get("variant") != variant:
            continue
        recs.append(r)
    return recs


def fmt_ms(s: float) -> str:
    return f"{s * 1e3:9.3f}"


def run(verbose: bool = True, variant: str | None = None) -> dict:
    recs = load(variant=variant)
    ok = [r for r in recs if r.get("ok")]
    bad = [r for r in recs if not r.get("ok")]
    rows = []
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"],
                                       r.get("variant", ""))):
        rl = r["roofline"]
        rows.append([
            r["arch"], r["shape"], r["mesh"], r.get("exec", "?"),
            r.get("variant", "?"),
            fmt_ms(rl["compute_s"]), fmt_ms(rl["memory_s"]),
            fmt_ms(rl["collective_s"]),
            rl["dominant"].replace("_s", ""),
            f"{r.get('useful_ratio', 0.0):.3f}",
            f"{rl.get('roofline_fraction', 0.0) * 100:5.1f}%",
            f"{r['memory']['peak_bytes'] / 2**30:7.2f}",
        ])
    if verbose:
        if rows:
            print(table("Roofline terms per cell (ms per step, per device)",
                        ["arch", "shape", "mesh", "exec", "variant",
                         "compute", "memory", "collective", "dominant",
                         "useful", "RL%", "peakGiB"], rows))
        for r in bad:
            print(f"  FAILED cell: {r['arch']}/{r['shape']}/{r['mesh']}: "
                  f"{r.get('error', '?')}")
        print(f"\n  {len(ok)} compiled cells, {len(bad)} failures")
        print()
    return {"ok": len(ok), "failed": len(bad), "records": ok}


if __name__ == "__main__":
    run()
