"""Serving-engine benchmark: continuous batching vs the legacy static batch.

The paper's deployment regime (weights stationary, tokens streaming) meets a
realistic request stream: staggered Poisson arrivals, ragged prompts,
per-request decode budgets. The legacy monolithic path must (a) WAIT for the
whole burst to arrive, (b) pad every prompt to one length, and (c) decode
the longest budget for everyone; the slot-based engine admits each request
on arrival, retires it at its own budget, and refills the slot immediately.

Measured per case (one transformer, one recurrent arch):
  * end-to-end throughput under the trace: useful tokens / makespan, where
    makespan runs from t=0 (first arrival is offset from it) to the last
    retirement — the continuous-batching win is the static path's dead
    arrival-wait + over-generation tail;
  * per-request latency percentiles (p50/p99) and TTFT;
  * CM_* ledger reconciliation on the programmed AIMC path;
  * engine compile counts (shape stability under the ragged trace);
  * bit-equality of engine vs static tokens for synchronized arrivals.

``--mesh data:D,model:M`` additionally benchmarks the SHARDED engine
(`runtime.engine.ShardedServeEngine`, DESIGN.md §11) against the
single-device engine on the same traces: decode slots sharded over the data
axis, programmed crossbar bit lines over the model axis. On the forced
host-platform mesh the devices share one CPU, so the point is not speedup —
it is that the sharded run is BIT-EQUAL to the single-device engine and
that the per-core/per-request CM_* ledgers still reconcile exactly
(EXPERIMENTS.md §Sharded serving). The flag forces
``--xla_force_host_platform_device_count`` as needed when run as a module.

``--json BENCH_serving.json`` is the machine-readable artifact
(``benchmarks.run --json`` includes this module; ``make bench-json``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Check, table
from repro.configs import get_arch
from repro.core.aimc import AimcConfig
from repro.core.program import MappingPlan, program_model
from repro.models.layers import Execution
from repro.runtime.batcher import (poisson_trace, reconcile, reconcile_cores,
                                   synchronized_trace)
from repro.runtime.engine import (ServeEngine, ShardedServeEngine,
                                  static_generate)

N_REQ = 16
RATE = 100.0                 # req/s: arrivals overlap decode at smoke scale
PROMPT = (4, 12)
MAX_NEW = (2, 16)            # wide budget spread: static decodes max for all
PAD = 12
N_SLOTS = 4


def _setup(arch: str, programmed: bool, n_contexts: int = 1):
    spec = get_arch(arch)
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    program = None
    if programmed:
        # fixed DAC input range (the deployment configuration): the dynamic
        # max-abs scale is computed over the whole flattened batch, so a
        # [1, P] engine prefill and a [B, P] static prefill would quantize
        # the same request differently — with a fixed scale the programmed
        # path is batch-size independent and engine == static bit-for-bit
        aimc_cfg = AimcConfig(impl="ref", input_scale=0.1)
        exe = Execution(mode="aimc", aimc=aimc_cfg, compute_dtype="float32",
                        programmed=True)
        program = program_model(params, MappingPlan(n_contexts=n_contexts),
                                aimc_cfg, jax.random.PRNGKey(2))
        params = program.install(params)
    else:
        exe = Execution(compute_dtype="float32")
    return spec, cfg, model, params, exe, program


def _serve_static_under_trace(model, cfg, exe, params, requests, max_seq):
    """The legacy path against a staggered trace: wait for the full burst,
    pad prompts to one length, decode the longest budget for everyone."""
    t_wait = max(r.arrival for r in requests)
    pad_id = 0
    prompts = jnp.asarray(
        [list(r.prompt) + [pad_id] * (PAD - len(r.prompt)) for r in requests],
        jnp.int32)
    gen = max(r.max_new for r in requests)
    # warm the static path's executables first — the engine's warmup is
    # outside its serving clock too, so neither side is billed for jit
    static_generate(model, cfg, exe, params, prompts, 2, max_seq=max_seq,
                    cache_dtype=jnp.float32)
    toks, (t_prefill, t_decode) = static_generate(
        model, cfg, exe, params, prompts, gen, max_seq=max_seq,
        cache_dtype=jnp.float32)
    makespan = t_wait + t_prefill + t_decode
    useful = sum(r.max_new for r in requests)
    over_gen = sum(gen - r.max_new for r in requests)
    lats = [makespan - r.arrival for r in requests]
    ttfts = [t_wait + t_prefill - r.arrival for r in requests]
    lats.sort()
    ttfts.sort()
    from repro.runtime.batcher import percentile
    return {
        "makespan_s": makespan,
        "useful_tokens": useful,
        "over_generated_tokens": over_gen,
        "tok_s": useful / makespan,
        "p50_latency_s": percentile(lats, 50),
        "p99_latency_s": percentile(lats, 99),
        "p50_ttft_s": percentile(ttfts, 50),
        "p99_ttft_s": percentile(ttfts, 99),
    }, toks


def _serve_continuous(engine, requests):
    report = engine.serve(requests)
    pct = report.latency_percentiles()
    return {
        "makespan_s": report.makespan_s,
        "useful_tokens": report.generated_tokens,
        "idle_lane_vectors": report.idle_vectors,
        "tok_s": report.generated_tokens / max(report.makespan_s, 1e-9),
        "n_decode_steps": report.n_steps,
        **pct,
    }, report


def _bench_case(arch: str, programmed: bool, verbose: bool) -> dict:
    spec, cfg, model, params, exe, program = _setup(arch, programmed)
    max_seq = PAD + MAX_NEW[1] + 2
    engine = ServeEngine(model, cfg, exe, params, n_slots=N_SLOTS,
                         prompt_pad=PAD, max_seq=max_seq,
                         cache_dtype=jnp.float32, family=spec.family,
                         module=spec.module, program=program)
    t0 = time.time()
    engine.warmup()
    t_warm = time.time() - t0

    trace = poisson_trace(N_REQ, RATE, seed=11, prompt_len=PROMPT,
                          max_new=MAX_NEW, vocab=cfg.vocab)
    cont, report = _serve_continuous(engine, trace)
    stat, _ = _serve_static_under_trace(model, cfg, exe, params, trace,
                                        max_seq)

    # synchronized arrivals: engine tokens must be bit-equal to static
    sync = synchronized_trace(N_SLOTS, prompt_len=PAD, max_new=6, seed=3,
                              vocab=cfg.vocab)
    sync_rep = engine.serve(sync)
    prompts = jnp.asarray([r.prompt for r in sync], jnp.int32)
    sync_toks, _ = static_generate(model, cfg, exe, params, prompts, 6,
                                   max_seq=max_seq, cache_dtype=jnp.float32)
    bit_equal = all(sync_rep.tokens(r.rid) == [int(t) for t in sync_toks[i]]
                    for i, r in enumerate(sync))

    # the ledger check crosses two independent countings: per-request
    # records vs the device loop's observed prefill/busy-lane vectors
    ledger_exact = report.observed_vectors == report.useful_vectors
    if program is not None:
        led_sum, static_sum = reconcile(program, report.records,
                                        report.observed_vectors)
        ledger_exact = ledger_exact and led_sum == static_sum

    case = {
        "arch": spec.arch_id,
        "exec": "aimc-programmed" if programmed else "digital",
        "trace": f"poisson:{RATE:.0f} n={N_REQ} prompt={PROMPT} "
                 f"max_new={MAX_NEW}",
        "n_slots": N_SLOTS,
        "warmup_s": t_warm,
        "continuous": cont,
        "static": stat,
        "tok_s_ratio": cont["tok_s"] / max(stat["tok_s"], 1e-9),
        "compile_counts": engine.compile_counts(),
        "stable_shapes": engine.compile_counts()
        == {"prefill": 1, "insert": 1, "decode": 1},
        "sync_bit_equal": bit_equal,
        "ledger_exact": ledger_exact,
    }
    if verbose:
        rows = [[mode, f"{d['tok_s']:.1f}", f"{d['makespan_s'] * 1e3:.0f}",
                 f"{d['p50_latency_s'] * 1e3:.0f}",
                 f"{d['p99_latency_s'] * 1e3:.0f}",
                 f"{d['p50_ttft_s'] * 1e3:.0f}"]
                for mode, d in (("static", stat), ("continuous", cont))]
        print(table(
            f"{spec.arch_id} [{case['exec']}] — {case['trace']}",
            ["path", "tok/s", "makespan ms", "p50 lat ms", "p99 lat ms",
             "p50 ttft ms"], rows))
        print(f"  continuous/static tok/s ratio: {case['tok_s_ratio']:.2f}  "
              f"(static over-generated {stat['over_generated_tokens']} "
              f"tokens, waited {max(r.arrival for r in trace) * 1e3:.0f}ms "
              f"for the burst)")
        print(f"  shape-stable: {case['stable_shapes']}  "
              f"sync bit-equal: {bit_equal}  ledger exact: {ledger_exact}")
    return case


def _bench_sharded_case(arch: str, programmed: bool, mesh, mesh_arg: str,
                        verbose: bool) -> dict:
    """Sharded vs single-device engine on identical traces (DESIGN.md §11):
    same params/program/trace, the only variable is the mesh placement."""
    from repro.core.schedule import CoreSchedule
    n_ctx = max(2, mesh.shape.get("model", 1)) if programmed else 1
    spec, cfg, model, params, exe, program = _setup(arch, programmed, n_ctx)
    schedule = (CoreSchedule.from_program(program)
                if program is not None else None)
    max_seq = PAD + MAX_NEW[1] + 2
    kw = dict(n_slots=N_SLOTS, prompt_pad=PAD, max_seq=max_seq,
              cache_dtype=jnp.float32, family=spec.family,
              module=spec.module, program=program, schedule=schedule)
    single = ServeEngine(model, cfg, exe, params, **kw)
    single.warmup()
    t0 = time.time()
    sharded = ShardedServeEngine(model, cfg, exe, params, mesh=mesh, **kw)
    sharded.warmup()
    t_warm = time.time() - t0

    trace = poisson_trace(N_REQ, RATE, seed=11, prompt_len=PROMPT,
                          max_new=MAX_NEW, vocab=cfg.vocab)
    cont_single, _ = _serve_continuous(single, trace)
    cont_sharded, rep_sharded = _serve_continuous(sharded, trace)

    # the equality bar: the SAME trace decodes to the SAME tokens on the
    # mesh as on one device (every request, every token)
    sync = synchronized_trace(N_SLOTS, prompt_len=PAD, max_new=6, seed=3,
                              vocab=cfg.vocab)
    sync_single = single.serve(sync)
    sync_sharded = sharded.serve(sync)
    bit_equal = all(sync_single.tokens(r.rid) == sync_sharded.tokens(r.rid)
                    for r in sync)

    ledger_exact = (rep_sharded.observed_vectors
                    == rep_sharded.useful_vectors)
    if program is not None:
        led_sum, static_sum = reconcile(program, rep_sharded.records,
                                        rep_sharded.observed_vectors)
        core_sum, sched_total = reconcile_cores(
            schedule, rep_sharded.records, rep_sharded.observed_vectors)
        ledger_exact = (ledger_exact and led_sum == static_sum
                        and core_sum == sched_total
                        and sched_total == program.mvm_counts().scaled(
                            rep_sharded.observed_vectors))

    case = {
        "arch": spec.arch_id,
        "exec": "aimc-programmed" if programmed else "digital",
        "mesh": mesh_arg,
        "trace": f"poisson:{RATE:.0f} n={N_REQ} prompt={PROMPT} "
                 f"max_new={MAX_NEW}",
        "n_slots": N_SLOTS,
        "warmup_s": t_warm,
        "single": cont_single,
        "sharded": cont_sharded,
        "tok_s_ratio": cont_sharded["tok_s"] / max(cont_single["tok_s"],
                                                   1e-9),
        "compile_counts": sharded.compile_counts(),
        "stable_shapes": sharded.compile_counts()
        == {"prefill": 1, "insert": 1, "decode": 1},
        "sync_bit_equal": bit_equal,
        "ledger_exact": ledger_exact,
    }
    if verbose:
        rows = [[mode, f"{d['tok_s']:.1f}", f"{d['makespan_s'] * 1e3:.0f}",
                 f"{d['p50_latency_s'] * 1e3:.0f}",
                 f"{d['p99_latency_s'] * 1e3:.0f}",
                 f"{d['p50_ttft_s'] * 1e3:.0f}"]
                for mode, d in (("single-device", cont_single),
                                ("sharded", cont_sharded))]
        print(table(
            f"{spec.arch_id} [{case['exec']}] engine on mesh {mesh_arg}",
            ["engine", "tok/s", "makespan ms", "p50 lat ms", "p99 lat ms",
             "p50 ttft ms"], rows))
        print(f"  sharded/single tok/s ratio: {case['tok_s_ratio']:.2f} "
              f"(host-platform devices share one CPU; equality, not "
              f"speedup, is the bar)")
        print(f"  shape-stable: {case['stable_shapes']}  "
              f"sync bit-equal: {bit_equal}  ledger exact: {ledger_exact}")
    return case


def run(verbose: bool = True, mesh_arg: str | None = None) -> dict:
    cases = [
        _bench_case("granite-8b", programmed=True, verbose=verbose),
        _bench_case("xlstm-350m", programmed=False, verbose=verbose),
    ]
    out = {"cases": cases}
    if mesh_arg:
        from repro.launch.mesh import make_mesh
        from repro.launch.serve import parse_named_mesh
        shape, axes = parse_named_mesh(mesh_arg)
        mesh = make_mesh(shape, axes)
        out["sharded_cases"] = [
            _bench_sharded_case("granite-8b", True, mesh, mesh_arg, verbose),
            _bench_sharded_case("xlstm-350m", False, mesh, mesh_arg,
                                verbose),
        ]
    return out


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    cases = results["cases"]
    min_ratio = min(c["tok_s_ratio"] for c in cases)
    out = [
        Check("continuous batching beats static tok/s on every "
              "staggered trace",
              1.0 if min_ratio > 1.0 else 0.0, 1.0, rtol=0.01),
        Check("engine shapes jit-stable over ragged traces (no recompile)",
              1.0 if all(c["stable_shapes"] for c in cases) else 0.0,
              1.0, rtol=0.01),
        Check("synchronized arrivals bit-equal to the static path",
              1.0 if all(c["sync_bit_equal"] for c in cases) else 0.0,
              1.0, rtol=0.01),
        Check("per-request CM_* ledgers reconcile with AimcProgram",
              1.0 if all(c["ledger_exact"] for c in cases) else 0.0,
              1.0, rtol=0.01),
    ]
    sharded = results.get("sharded_cases")
    if sharded:
        out += [
            Check("sharded engine bit-equal to single-device on the mesh",
                  1.0 if all(c["sync_bit_equal"] for c in sharded) else 0.0,
                  1.0, rtol=0.01),
            Check("sharded engine shapes jit-stable (no recompile)",
                  1.0 if all(c["stable_shapes"] for c in sharded) else 0.0,
                  1.0, rtol=0.01),
            Check("shard-aggregated per-core ledgers reconcile exactly",
                  1.0 if all(c["ledger_exact"] for c in sharded) else 0.0,
                  1.0, rtol=0.01),
        ]
    return out


if __name__ == "__main__":
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH",
                    help="write results + checks as JSON")
    ap.add_argument("--mesh", metavar="SPEC", default=None,
                    help="also bench the sharded engine on this mesh "
                         "(data:D,model:M); forces host-platform device "
                         "count as needed")
    args = ap.parse_args()
    if args.mesh:
        # must precede first backend use: XLA fixes the device count at init
        from repro.launch.serve import force_host_device_count
        force_host_device_count(args.mesh)
    res = run(mesh_arg=args.mesh)
    cs = checks(res)
    for c in cs:
        print(c.row())
    if args.json:
        payload = {"results": res,
                   "checks": [{"name": c.name, "measured": c.measured,
                               "target": c.target, "ok": c.ok} for c in cs]}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")
    sys.exit(0 if all(c.ok for c in cs) else 1)
