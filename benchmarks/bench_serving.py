"""Serving-engine benchmark: continuous batching vs the legacy static batch.

The paper's deployment regime (weights stationary, tokens streaming) meets a
realistic request stream: staggered Poisson arrivals, ragged prompts,
per-request decode budgets. The legacy monolithic path must (a) WAIT for the
whole burst to arrive, (b) pad every prompt to one length, and (c) decode
the longest budget for everyone; the slot-based engine admits each request
on arrival, retires it at its own budget, and refills the slot immediately.

Measured per case (one transformer, one recurrent arch):
  * end-to-end throughput under the trace: useful tokens / makespan, where
    makespan runs from t=0 (first arrival is offset from it) to the last
    retirement — the continuous-batching win is the static path's dead
    arrival-wait + over-generation tail;
  * per-request latency percentiles (p50/p99) and TTFT;
  * CM_* ledger reconciliation on the programmed AIMC path;
  * engine compile counts (shape stability under the ragged trace);
  * bit-equality of engine vs static tokens for synchronized arrivals.

``--mesh data:D,model:M`` additionally benchmarks the SHARDED engine
(`runtime.engine.ShardedServeEngine`, DESIGN.md §11/§13) against the
single-device engine on the same traces: decode slots sharded over the data
axis, programmed crossbar bit lines over the model axis. The sharded sweep
runs the k-step chunked decode loop at every k in ``CHUNKS``: per-step host
rounds are what made the PR-5 sharded engine LOSE to one device (each
dispatch/sync round trip is paid per token), and the k-step `lax.scan`
chunk amortizes that round over k tokens.

The sharded gates are STEP-LEVEL, because on the forced host-platform mesh
the "devices" are threads sharing one physical CPU: total compute is
conserved, so an end-to-end cross-device speedup is not physically on the
table at smoke scale (per-call SPMD dispatch and thread contention are pure
overhead — the seed benchmark said as much). What the chunk is responsible
for — the per-token host round — IS measurable and gated: (a) the k=1 ->
k=max saturated step-time gain must exceed ``CHUNK_GAIN_MIN`` for the
arch where the round DOMINATES the step (the recurrent arch: its light
step makes the round ~half of k=1 cost and the sweep shows 1.6-2.2x;
the transformer's heavy step caps its ceiling at ~1.2-1.3x, inside
measurement noise, so its gain is recorded but not gated), (b) the
residual host-round share of the step at
k=max — (t_round/k) / T_step(k) from the fitted roofline — must fall
under ``ROUND_SHARE_MAX`` (the round no longer matters; at k=1 it is
25-50% of every step), and (c) each case fits
`core.schedule.OverlapRoofline`
(T_step(k) = t_step_s + t_round_s / k) to the measured per-step times and
gates the fit residual plus the PREDICTED 1->k overlap gain against the
MEASURED step-time delta — the speedup is explained, not just observed.
On real multi-device hardware the same discipline is what lets data:N win
end-to-end; here the end-to-end tok/s of every k is recorded for
transparency but not gated. Throughout, the chunked run must stay
BIT-EQUAL to the single-device engine and the per-core/per-request CM_*
ledgers must reconcile exactly (EXPERIMENTS.md §Sharded serving). The flag
forces ``--xla_force_host_platform_device_count`` as needed when run as a
module.

The paged case (DESIGN.md §15) benches the paged KV cache on a
shared-system-prompt trace: dense vs paged+chunked-prefill with the
content-hashed prefix cache off and on. Gated: bit-equality across all
three arms, prefix-on >= ``PREFIX_GAIN_MIN``x tok/s over prefix-off with
the shared span prefilled exactly once, chunked legs cutting the dense
path's prompt-pad waste (``prefill_pad_vectors`` before/after), and exact
CM_* + page-ledger reconciliation; the dense-vs-paged KV footprint and the
deduplicated shared-span bytes are recorded.

``--json BENCH_serving.json`` is the machine-readable artifact
(``benchmarks.run --json`` includes this module; ``make bench-json``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Check, table
from repro.configs import get_arch
from repro.core.aimc import AimcConfig
from repro.core.program import MappingPlan, program_model
from repro.models.layers import Execution
from repro.runtime.batcher import (poisson_trace, reconcile, reconcile_cores,
                                   synchronized_trace)
from repro.runtime.engine import (ServeEngine, ShardedServeEngine,
                                  static_generate)

N_REQ = 16
RATE = 100.0                 # req/s: arrivals overlap decode at smoke scale
PROMPT = (4, 12)
MAX_NEW = (2, 16)            # wide budget spread: static decodes max for all
PAD = 12
N_SLOTS = 4
CHUNKS = (1, 4, 8)           # decode_chunk sweep for the sharded engine
ROOFLINE_RTOL = 0.35         # fit residual / predicted-vs-measured gate
CHUNK_GAIN_MIN = 1.25        # k-sweep step gain where the round dominates
ROUND_SHARE_MAX = 0.20       # residual host-round share of the step at k=max

# paged-engine case (DESIGN.md §15): shared-system-prompt trace
P_PAD = 48                   # prompt pad — the system prompt dominates
P_SHARED = 40                # shared system-prompt span (5 full pages)
P_PAGE = 8                   # KV page size
P_CHUNK = 8                  # prefill-chunk leg width (both prefix arms)
P_REQ = 8                    # requests sharing the system prompt
PREFIX_GAIN_MIN = 1.3        # prefix-cache on/off tok/s gate


def _setup(arch: str, programmed: bool, n_contexts: int = 1):
    spec = get_arch(arch)
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    program = None
    if programmed:
        # fixed DAC input range (the deployment configuration): the dynamic
        # max-abs scale is computed over the whole flattened batch, so a
        # [1, P] engine prefill and a [B, P] static prefill would quantize
        # the same request differently — with a fixed scale the programmed
        # path is batch-size independent and engine == static bit-for-bit
        aimc_cfg = AimcConfig(impl="ref", input_scale=0.1)
        exe = Execution(mode="aimc", aimc=aimc_cfg, compute_dtype="float32",
                        programmed=True)
        program = program_model(params, MappingPlan(n_contexts=n_contexts),
                                aimc_cfg, jax.random.PRNGKey(2))
        params = program.install(params)
    else:
        exe = Execution(compute_dtype="float32")
    return spec, cfg, model, params, exe, program


def _serve_static_under_trace(model, cfg, exe, params, requests, max_seq):
    """The legacy path against a staggered trace: wait for the full burst,
    pad prompts to one length, decode the longest budget for everyone."""
    t_wait = max(r.arrival for r in requests)
    pad_id = 0
    prompts = jnp.asarray(
        [list(r.prompt) + [pad_id] * (PAD - len(r.prompt)) for r in requests],
        jnp.int32)
    gen = max(r.max_new for r in requests)
    # warm the static path's executables first — the engine's warmup is
    # outside its serving clock too, so neither side is billed for jit
    static_generate(model, cfg, exe, params, prompts, 2, max_seq=max_seq,
                    cache_dtype=jnp.float32)
    toks, (t_prefill, t_decode) = static_generate(
        model, cfg, exe, params, prompts, gen, max_seq=max_seq,
        cache_dtype=jnp.float32)
    makespan = t_wait + t_prefill + t_decode
    useful = sum(r.max_new for r in requests)
    over_gen = sum(gen - r.max_new for r in requests)
    lats = [makespan - r.arrival for r in requests]
    ttfts = [t_wait + t_prefill - r.arrival for r in requests]
    lats.sort()
    ttfts.sort()
    from repro.runtime.batcher import percentile
    return {
        "makespan_s": makespan,
        "useful_tokens": useful,
        "over_generated_tokens": over_gen,
        "tok_s": useful / makespan,
        "p50_latency_s": percentile(lats, 50),
        "p99_latency_s": percentile(lats, 99),
        "p50_ttft_s": percentile(ttfts, 50),
        "p99_ttft_s": percentile(ttfts, 99),
    }, toks


def _serve_continuous(engine, requests):
    report = engine.serve(requests)
    pct = report.latency_percentiles()
    return {
        "makespan_s": report.makespan_s,
        "useful_tokens": report.generated_tokens,
        "idle_lane_vectors": report.idle_vectors,
        "tok_s": report.generated_tokens / max(report.makespan_s, 1e-9),
        "n_decode_steps": report.n_steps,
        **pct,
    }, report


def _measure_step_time(engine, vocab: int, reps: int = 3) -> float:
    """Mean wall seconds per decode STEP (chunk wall / k) with every slot
    busy: a synchronized saturated trace keeps all lanes active so the
    measurement isolates host-round amortization, not slot raggedness.
    Best of ``reps`` serves shaves OS-scheduler noise off the roofline
    fit."""
    best = float("inf")
    for r in range(reps):
        sync = synchronized_trace(engine.n_slots, prompt_len=PAD,
                                  max_new=MAX_NEW[1], seed=5 + r,
                                  vocab=vocab)
        rep = engine.serve(sync)
        best = min(best, rep.wall_decode_s / max(rep.n_steps, 1))
    return best


def _bench_case(arch: str, programmed: bool, verbose: bool) -> dict:
    spec, cfg, model, params, exe, program = _setup(arch, programmed)
    max_seq = PAD + MAX_NEW[1] + 2
    engine = ServeEngine(model, cfg, exe, params, n_slots=N_SLOTS,
                         prompt_pad=PAD, max_seq=max_seq,
                         cache_dtype=jnp.float32, family=spec.family,
                         module=spec.module, program=program)
    t0 = time.time()
    engine.warmup()
    t_warm = time.time() - t0

    trace = poisson_trace(N_REQ, RATE, seed=11, prompt_len=PROMPT,
                          max_new=MAX_NEW, vocab=cfg.vocab)
    cont, report = _serve_continuous(engine, trace)
    stat, _ = _serve_static_under_trace(model, cfg, exe, params, trace,
                                        max_seq)

    # synchronized arrivals: engine tokens must be bit-equal to static
    sync = synchronized_trace(N_SLOTS, prompt_len=PAD, max_new=6, seed=3,
                              vocab=cfg.vocab)
    sync_rep = engine.serve(sync)
    prompts = jnp.asarray([r.prompt for r in sync], jnp.int32)
    sync_toks, _ = static_generate(model, cfg, exe, params, prompts, 6,
                                   max_seq=max_seq, cache_dtype=jnp.float32)
    bit_equal = all(sync_rep.tokens(r.rid) == [int(t) for t in sync_toks[i]]
                    for i, r in enumerate(sync))

    # the ledger check crosses two independent countings: per-request
    # records vs the device loop's observed prefill/busy-lane vectors
    ledger_exact = report.observed_vectors == report.useful_vectors
    if program is not None:
        led_sum, static_sum = reconcile(program, report.records,
                                        report.observed_vectors)
        ledger_exact = ledger_exact and led_sum == static_sum

    case = {
        "arch": spec.arch_id,
        "exec": "aimc-programmed" if programmed else "digital",
        "trace": f"poisson:{RATE:.0f} n={N_REQ} prompt={PROMPT} "
                 f"max_new={MAX_NEW}",
        "n_slots": N_SLOTS,
        "warmup_s": t_warm,
        "continuous": cont,
        "static": stat,
        "tok_s_ratio": cont["tok_s"] / max(stat["tok_s"], 1e-9),
        "compile_counts": engine.compile_counts(),
        "stable_shapes": engine.compile_counts()
        == {"prefill": 1, "insert": 1, "decode": 1},
        "sync_bit_equal": bit_equal,
        "ledger_exact": ledger_exact,
    }
    if verbose:
        rows = [[mode, f"{d['tok_s']:.1f}", f"{d['makespan_s'] * 1e3:.0f}",
                 f"{d['p50_latency_s'] * 1e3:.0f}",
                 f"{d['p99_latency_s'] * 1e3:.0f}",
                 f"{d['p50_ttft_s'] * 1e3:.0f}"]
                for mode, d in (("static", stat), ("continuous", cont))]
        print(table(
            f"{spec.arch_id} [{case['exec']}] — {case['trace']}",
            ["path", "tok/s", "makespan ms", "p50 lat ms", "p99 lat ms",
             "p50 ttft ms"], rows))
        print(f"  continuous/static tok/s ratio: {case['tok_s_ratio']:.2f}  "
              f"(static over-generated {stat['over_generated_tokens']} "
              f"tokens, waited {max(r.arrival for r in trace) * 1e3:.0f}ms "
              f"for the burst)")
        print(f"  shape-stable: {case['stable_shapes']}  "
              f"sync bit-equal: {bit_equal}  ledger exact: {ledger_exact}")
    return case


def _bench_sharded_case(arch: str, programmed: bool, mesh, mesh_arg: str,
                        verbose: bool, chunks=CHUNKS) -> dict:
    """Sharded chunked-decode sweep vs the single-device engine on
    identical traces (DESIGN.md §11/§13): same params/program/trace, the
    variables are the mesh placement and the decode chunk size k. Fits
    `OverlapRoofline` to the measured per-step times across k and records
    both the predicted and the realized overlap gain."""
    from repro.core.schedule import CoreSchedule, OverlapRoofline
    n_ctx = max(2, mesh.shape.get("model", 1)) if programmed else 1
    spec, cfg, model, params, exe, program = _setup(arch, programmed, n_ctx)
    schedule = (CoreSchedule.from_program(program)
                if program is not None else None)
    max_seq = PAD + MAX_NEW[1] + 2
    kw = dict(n_slots=N_SLOTS, prompt_pad=PAD, max_seq=max_seq,
              cache_dtype=jnp.float32, family=spec.family,
              module=spec.module, program=program, schedule=schedule)
    single = ServeEngine(model, cfg, exe, params, **kw)
    single.warmup()

    trace = poisson_trace(N_REQ, RATE, seed=11, prompt_len=PROMPT,
                          max_new=MAX_NEW, vocab=cfg.vocab)
    cont_single, _ = _serve_continuous(single, trace)
    cont_single["step_s"] = _measure_step_time(single, cfg.vocab)
    sync = synchronized_trace(N_SLOTS, prompt_len=PAD, max_new=6, seed=3,
                              vocab=cfg.vocab)
    sync_single = single.serve(sync)

    by_chunk = {}
    step_times = {}
    bit_equal = ledger_exact = stable = True
    t_warm = 0.0
    best_k = chunks[0]
    for k in chunks:
        t0 = time.time()
        sharded = ShardedServeEngine(model, cfg, exe, params, mesh=mesh,
                                     decode_chunk=k, **kw)
        sharded.warmup()
        t_warm += time.time() - t0
        cont_sharded, rep_sharded = _serve_continuous(sharded, trace)
        step_times[k] = _measure_step_time(sharded, cfg.vocab)
        cont_sharded["step_s"] = step_times[k]

        # the equality bar AT EVERY k: the same trace decodes to the same
        # tokens on the mesh, whatever the chunk size (every request,
        # every token)
        sync_sharded = sharded.serve(sync)
        bit_equal = bit_equal and all(
            sync_single.tokens(r.rid) == sync_sharded.tokens(r.rid)
            for r in sync)
        ok = rep_sharded.observed_vectors == rep_sharded.useful_vectors
        if program is not None:
            led_sum, static_sum = reconcile(program, rep_sharded.records,
                                            rep_sharded.observed_vectors)
            core_sum, sched_total = reconcile_cores(
                schedule, rep_sharded.records, rep_sharded.observed_vectors)
            ok = (ok and led_sum == static_sum and core_sum == sched_total
                  and sched_total == program.mvm_counts().scaled(
                      rep_sharded.observed_vectors))
        ledger_exact = ledger_exact and ok
        # decode holds one executable per compiled ladder length (powers of
        # two up to k), all built at warmup; serving must not add any
        stable = stable and (sharded.compile_counts()
                             == {"prefill": 1, "insert": 1,
                                 "decode": len(sharded._ladder)})
        by_chunk[str(k)] = cont_sharded
        if cont_sharded["tok_s"] > by_chunk[str(best_k)]["tok_s"]:
            best_k = k

    # calibrated overlap roofline: T_step(k) = t_step_s + t_round_s / k.
    # predicted 1->k_max gain must EXPLAIN the measured step-time delta.
    roofline = OverlapRoofline.fit(step_times)
    k_lo, k_hi = min(chunks), max(chunks)
    measured_gain = step_times[k_lo] / max(step_times[k_hi], 1e-12)
    predicted_gain = roofline.speedup(k_lo, k_hi)
    residual = max(roofline.residuals(step_times).values())

    best = by_chunk[str(best_k)]
    # best-k sharded per-step cost relative to the single-device engine's
    # (recorded for transparency: the residual over 1.0 at large k is SPMD
    # compute overhead — the thread-devices split one CPU — not the host
    # round, which the gated round-share isolates)
    step_ratio = (min(step_times.values())
                  / max(cont_single["step_s"], 1e-12))
    # the gated step-level recovery: what fraction of a step is still the
    # host round at k=k_hi, per the fitted roofline (25-50% at k=1)
    round_share = ((roofline.t_round_s / k_hi)
                   / max(roofline.predict_step_s(k_hi), 1e-12))
    case = {
        "arch": spec.arch_id,
        "exec": "aimc-programmed" if programmed else "digital",
        "mesh": mesh_arg,
        "trace": f"poisson:{RATE:.0f} n={N_REQ} prompt={PROMPT} "
                 f"max_new={MAX_NEW}",
        "n_slots": N_SLOTS,
        "chunks": list(chunks),
        "warmup_s": t_warm,
        "single": cont_single,
        "sharded_by_chunk": by_chunk,
        "best_chunk": best_k,
        "sharded": best,
        "tok_s_ratio": best["tok_s"] / max(cont_single["tok_s"], 1e-9),
        "tok_s_ratio_k1": (by_chunk[str(k_lo)]["tok_s"]
                           / max(cont_single["tok_s"], 1e-9)),
        "step_ratio": step_ratio,
        "chunk_step_gain": step_times[k_lo] / max(min(step_times.values()),
                                                  1e-12),
        "round_share_k_hi": round_share,
        "round_share_k1": (roofline.t_round_s
                           / max(roofline.predict_step_s(k_lo), 1e-12)),
        "roofline": {
            "t_step_s": roofline.t_step_s,
            "t_round_s": roofline.t_round_s,
            "fit_residual_max": residual,
            "predicted_gain": predicted_gain,
            "measured_gain": measured_gain,
            "k_lo": k_lo, "k_hi": k_hi,
        },
        "stable_shapes": stable,
        "sync_bit_equal": bit_equal,
        "ledger_exact": ledger_exact,
    }
    if verbose:
        rows = [["single k=1", f"{cont_single['tok_s']:.1f}",
                 f"{cont_single['step_s'] * 1e3:.2f}",
                 f"{cont_single['makespan_s'] * 1e3:.0f}",
                 f"{cont_single['p50_latency_s'] * 1e3:.0f}",
                 f"{cont_single['p99_latency_s'] * 1e3:.0f}"]]
        rows += [[f"sharded k={k}", f"{d['tok_s']:.1f}",
                  f"{d['step_s'] * 1e3:.2f}",
                  f"{d['makespan_s'] * 1e3:.0f}",
                  f"{d['p50_latency_s'] * 1e3:.0f}",
                  f"{d['p99_latency_s'] * 1e3:.0f}"]
                 for k, d in by_chunk.items()]
        print(table(
            f"{spec.arch_id} [{case['exec']}] engine on mesh {mesh_arg}",
            ["engine", "tok/s", "step ms", "makespan ms", "p50 lat ms",
             "p99 lat ms"], rows))
        print(f"  best chunk k={best_k}: sharded/single tok/s ratio "
              f"{case['tok_s_ratio']:.2f} (was {case['tok_s_ratio_k1']:.2f}"
              f" at k=1); per-step cost {case['step_ratio']:.2f}x single, "
              f"chunk step gain {case['chunk_step_gain']:.2f}x over k=1, "
              f"host-round share {case['round_share_k1']:.0%} -> "
              f"{case['round_share_k_hi']:.0%}")
        print(f"  roofline: t_step={roofline.t_step_s * 1e3:.2f}ms "
              f"t_round={roofline.t_round_s * 1e3:.2f}ms  "
              f"predicted {k_lo}->{k_hi} gain {predicted_gain:.2f}x vs "
              f"measured {measured_gain:.2f}x  (max residual "
              f"{residual:.2%})")
        print(f"  shape-stable: {stable}  sync bit-equal: {bit_equal}  "
              f"ledger exact: {ledger_exact}")
    return case


def _shared_prompt_trace(n: int, vocab: int, seed: int = 7):
    """``n`` synchronized requests sharing one ``P_SHARED``-token system
    prompt, each with a unique 4..(P_PAD - P_SHARED)-token suffix — the
    deployment shape the content-hashed prefix cache exists for."""
    import random

    from repro.runtime.batcher import Request
    rng = random.Random(seed)
    shared = tuple(rng.randint(1, vocab - 1) for _ in range(P_SHARED))
    out = []
    for i in range(n):
        sfx = tuple(rng.randint(1, vocab - 1)
                    for _ in range(rng.randint(4, P_PAD - P_SHARED)))
        out.append(Request(rid=i, prompt=shared + sfx, max_new=3,
                           arrival=0.0))
    return out


def _cache_bytes(engine) -> int:
    """Total bytes of the engine's session KV storage (dense slot cache or
    paged pools + page table)."""
    return sum(x.nbytes for x in
               jax.tree_util.tree_leaves(engine._empty_cache()))


def _bench_paged_case(verbose: bool) -> dict:
    """Paged KV cache + content-hashed prefix cache + chunked prefill
    (DESIGN.md §15) on a shared-system-prompt trace.

    Three engines over the SAME trace — dense (the before: every prefill
    pays the full ``P_PAD`` pad width and every request re-prefills the
    shared span), paged+chunked with the prefix cache OFF, and the same
    with it ON. Single decode slot so admission order is deterministic and
    the exactly-once contract is checkable under chunking: request 0
    produces the shared pages, every later admission hits them and prefills
    only its unique suffix. Gates: bit-equality across all three engines,
    prefix-on >= ``PREFIX_GAIN_MIN``x tok/s over prefix-off (same chunking,
    the ONLY toggle is the prefix cache), shared span prefilled exactly
    once, chunked legs cut the dense path's prompt-pad waste, and CM_* +
    page ledgers reconcile exactly."""
    spec, cfg, model, params, exe, program = _setup("granite-8b", True)
    max_seq = P_PAD + 8
    n_pages = 16            # one max-length request + the held prefix + slack
    kw = dict(n_slots=1, prompt_pad=P_PAD, max_seq=max_seq,
              cache_dtype=jnp.float32, family=spec.family,
              module=spec.module, program=program)
    trace = _shared_prompt_trace(P_REQ, cfg.vocab)
    plens = [len(r.prompt) for r in trace]

    arms = {}
    reports = {}
    counts = {}
    bytes_of = {}
    engines = {}
    for name, extra in (
            ("dense", {}),
            ("paged_off", dict(page_size=P_PAGE, n_pages=n_pages,
                               prefill_chunk=P_CHUNK)),
            ("paged_on", dict(page_size=P_PAGE, n_pages=n_pages,
                              prefill_chunk=P_CHUNK, prefix_cache=True))):
        eng = ServeEngine(model, cfg, exe, params, **kw, **extra)
        counts0 = eng.warmup()
        bytes_of[name] = _cache_bytes(eng)
        stats, rep = _serve_continuous(eng, list(trace))
        ok = rep.observed_vectors == rep.useful_vectors
        led_sum, static_sum = reconcile(program, rep.records,
                                        rep.observed_vectors)
        ok = ok and led_sum == static_sum and rep.page_ledger_exact
        stats["ledger_exact"] = ok
        stats["stable_shapes"] = eng.compile_counts() == counts0
        stats["prefill_pad_vectors"] = rep.prefill_pad_vectors
        arms[name] = stats
        reports[name] = rep
        counts[name] = eng.compile_counts()
        engines[name] = eng

    bit_equal = all(
        reports["dense"].tokens(r.rid) == reports[name].tokens(r.rid)
        for r in trace for name in ("paged_off", "paged_on"))

    on = reports["paged_on"]
    # exactly-once: request 0 pays its full prompt, every other request
    # pays ONLY its continuation past the page-aligned shared span
    span = (P_SHARED // P_PAGE) * P_PAGE
    paid = [on.records[r.rid].prefill_vectors for r in trace]
    exactly_once = (
        on.prefix_hits == P_REQ - 1
        and on.prefix_hit_vectors == span * (P_REQ - 1)
        and paid == [plens[0]] + [p - span for p in plens[1:]])

    gain = arms["paged_on"]["tok_s"] / max(arms["paged_off"]["tok_s"], 1e-9)

    # pad-waste before/after on a RAGGED short-prompt trace: the dense
    # path pads every prompt to P_PAD rows, so prompts far below the pad
    # burn (P_PAD - plen) lanes each; chunked legs only round up to the
    # leg width. (The shared-prompt trace above sits near the pad on
    # purpose, so it can't show this.) Engines are reusable across serves.
    ragged = poisson_trace(P_REQ, RATE, seed=13, prompt_len=(4, 12),
                           max_new=(2, 4), vocab=cfg.vocab)
    pad_waste = {}
    for name in ("dense", "paged_on"):
        eng = engines[name]
        rep = eng.serve(list(ragged))
        pad_waste[name] = rep.prefill_pad_vectors
    pad_cut = pad_waste["paged_on"] < pad_waste["dense"]

    # footprint: dense stores the shared span once PER SLOT CONTEXT; the
    # paged pool stores it once, refcounted. Bytes per token row derived
    # from the dense cache (covers K+V across all layers).
    row_bytes = bytes_of["dense"] // max_seq        # n_slots=1
    case = {
        "arch": spec.arch_id,
        "exec": "aimc-programmed",
        "trace": f"sync n={P_REQ} shared_prefix={P_SHARED} "
                 f"prompt<=P_PAD={P_PAD} max_new=3",
        "page_size": P_PAGE, "n_pages": n_pages, "prefill_chunk": P_CHUNK,
        "arms": arms,
        "prefix_tok_s_gain": gain,
        "prefix_hits": on.prefix_hits,
        "prefix_hit_vectors": on.prefix_hit_vectors,
        "prefill_vectors_paid": paid,
        "exactly_once": exactly_once,
        "pad_trace": f"poisson:{RATE:.0f} n={P_REQ} prompt=(4, 12) "
                     f"max_new=(2, 4)",
        "pad_waste_before": pad_waste["dense"],
        "pad_waste_after": pad_waste["paged_on"],
        "pad_waste_cut": pad_cut,
        "footprint": {
            "dense_cache_bytes": bytes_of["dense"],
            "paged_cache_bytes": bytes_of["paged_on"],
            "row_bytes": row_bytes,
            # KV bytes the prefix cache avoids duplicating across the trace
            "shared_span_bytes_saved": (P_REQ - 1) * span * row_bytes,
        },
        "compile_counts": counts["paged_on"],
        "sync_bit_equal": bit_equal,
        "stable_shapes": all(a["stable_shapes"] for a in arms.values()),
        "ledger_exact": all(a["ledger_exact"] for a in arms.values()),
    }
    if verbose:
        rows = [[name, f"{a['tok_s']:.1f}",
                 f"{a['makespan_s'] * 1e3:.0f}",
                 f"{a['prefill_pad_vectors']}",
                 f"{a['p50_ttft_s'] * 1e3:.0f}"]
                for name, a in arms.items()]
        print(table(
            f"{spec.arch_id} [aimc-programmed] paged engine — "
            f"{case['trace']}",
            ["arm", "tok/s", "makespan ms", "pad waste", "p50 ttft ms"],
            rows))
        fp = case["footprint"]
        print(f"  prefix cache on/off tok/s gain: {gain:.2f}x "
              f"(gate >= {PREFIX_GAIN_MIN}x); hits {on.prefix_hits}/"
              f"{P_REQ - 1}, {on.prefix_hit_vectors} prompt vectors never "
              f"re-prefilled; exactly-once: {exactly_once}")
        print(f"  ragged-trace pad waste {case['pad_waste_before']} -> "
              f"{case['pad_waste_after']} vectors (full-pad prefill vs "
              f"chunk={P_CHUNK} legs); shared-span KV deduplicated: "
              f"{fp['shared_span_bytes_saved'] / 1e6:.2f} MB across "
              f"{P_REQ} requests")
        print(f"  bit-equal: {bit_equal}  shape-stable: "
              f"{case['stable_shapes']}  ledger exact: "
              f"{case['ledger_exact']}")
    return case


def _bench_drift_case(arch: str, verbose: bool) -> dict:
    """Drift-aware serving (DESIGN.md §14): accuracy vs program age, the
    hot-recalibration cost, and a chaos-grade mid-trace kill.

    Three legs, three gates:
      * probe error GROWS with program age under the power-law drift model
        and crosses the health threshold (there is something to repair);
      * after hot recalibration the probe error returns to the FRESH
        tolerance — reprogramming under the original keys is bit-exact,
        so the recovery is exact, not approximate;
      * a deterministic mid-trace core kill through the engine loses zero
        requests, stays bit-equal to the unfaulted run, and closes the
        CM_* books exactly INCLUDING the extra recal CM_INITIALIZE.
    """
    from repro.core import noise as noise_lib
    from repro.core.schedule import CoreSchedule
    from repro.runtime.chaos import parse_chaos
    from repro.runtime.health import build_health, reconcile_recal

    spec = get_arch(arch)
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    aimc_cfg = AimcConfig(impl="ref", input_scale=0.1)
    exe = Execution(mode="aimc", aimc=aimc_cfg, compute_dtype="float32",
                    programmed=True)
    plan = MappingPlan(n_contexts=2)
    key = jax.random.PRNGKey(2)
    program = program_model(params, plan, aimc_cfg, key)
    schedule = CoreSchedule.from_program(program)

    # -- accuracy vs program age (probe error against the fresh oracle) -----
    drift = noise_lib.drift_only(nu=0.05, t0=0.01)
    health = build_health(program, params, plan, key, noise=drift)
    fresh = dict(zip(program.names, program.states))
    err_fresh = max(health.probe(fresh, 0.0).errors.values())
    age_curve = {}
    for age in (0.01, 0.1, 1.0, 10.0, 100.0):
        entries = program.aged_entries(age, drift) or fresh
        sample = health.probe(entries, age)
        age_curve[str(age)] = max(sample.errors.values())
    t_old = 100.0
    aged = program.aged_entries(t_old, drift)
    failing = health.failing_cores(health.probe(aged, t_old))
    t0 = time.time()
    entries, names, cm = health.recalibrate(failing, t_old)
    recal_wall_s = time.time() - t0
    err_recal = max(health.probe(
        {**aged, **entries}, t_old + 1e-3).errors.values())

    # -- chaos leg: mid-trace kill through the engine ------------------------
    max_seq = PAD + MAX_NEW[1] + 2
    kw = dict(n_slots=N_SLOTS, prompt_pad=PAD, max_seq=max_seq,
              cache_dtype=jnp.float32, family=spec.family,
              module=spec.module, program=program, schedule=schedule,
              decode_chunk=4)
    trace = poisson_trace(N_REQ, RATE, seed=11, prompt_len=PROMPT,
                          max_new=MAX_NEW, vocab=cfg.vocab)
    ref_eng = ServeEngine(model, cfg, exe, program.install(params), **kw)
    ref_eng.warmup()
    ref = ref_eng.serve(list(trace))

    chaos = parse_chaos("kill:1@2")
    chaos_health = build_health(program, params, plan, key)
    eng = ServeEngine(model, cfg, exe, program.install(params),
                      health=chaos_health, chaos=chaos, **kw)
    eng.warmup()
    rep = eng.serve(list(trace))

    lost = len(trace) - len(rep.records)
    bit_equal = all(rep.tokens(r.rid) == ref.tokens(r.rid) for r in trace)
    led_sum, static_sum = reconcile(eng.program, rep.records,
                                    rep.observed_vectors)
    books_exact = (lost == 0 and chaos.exhausted and rep.n_recals >= 1
                   and led_sum == static_sum
                   and reconcile_recal(eng.program, rep)
                   and rep.recal_initialize > 0)

    session_init = program.initialize_counts().initialize
    case = {
        "arch": spec.arch_id,
        "drift": {"nu": drift.drift_nu, "t0_s": drift.drift_t0},
        "health_threshold": health.policy.threshold,
        "probe_err_fresh": err_fresh,
        "probe_err_by_age_s": age_curve,
        "probe_err_after_recal": err_recal,
        "recal": {
            "cores": list(failing),
            "n_matrices": len(names),
            "cm_initialize": cm.initialize,
            "session_cm_initialize": session_init,
            "cost_vs_session": cm.initialize / max(session_init, 1),
            "wall_s": recal_wall_s,
        },
        "chaos": {
            "spec": "kill:1@2",
            "lost_requests": lost,
            "n_recals": rep.n_recals,
            "recal_cm_initialize": rep.recal_initialize,
            "probes": rep.probes,
            "wall_health_s": rep.wall_health_s,
            "bit_equal": bit_equal,
            "books_exact": books_exact,
            "straggler_exempted": len(eng.monitor.exempted),
        },
        "drift_detected": age_curve[str(t_old)] > health.policy.threshold,
        "recal_recovers": err_recal <= err_fresh + 1e-6,
    }
    if verbose:
        rows = [[age, f"{err:.4f}"] for age, err in age_curve.items()]
        rows.append(["after recal", f"{err_recal:.4f}"])
        print(table(
            f"{spec.arch_id} [aimc-programmed] drift nu={drift.drift_nu:g} "
            f"t0={drift.drift_t0:g}s — max per-core probe error",
            ["program age (s)", "rel err"], rows))
        print(f"  recal: {len(names)} matrices on cores {list(failing)}, "
              f"CM_INITIALIZE={cm.initialize} "
              f"({case['recal']['cost_vs_session']:.0%} of the session "
              f"program bill), {recal_wall_s * 1e3:.0f}ms wall")
        print(f"  chaos kill:1@2: lost={lost} bit-equal={bit_equal} "
              f"books-exact={books_exact} recal CM_INITIALIZE="
              f"{rep.recal_initialize} exempted-chunks="
              f"{case['chaos']['straggler_exempted']}")
    return case


def run(verbose: bool = True, mesh_arg: str | None = None) -> dict:
    cases = [
        _bench_case("granite-8b", programmed=True, verbose=verbose),
        _bench_case("xlstm-350m", programmed=False, verbose=verbose),
    ]
    out = {"cases": cases,
           "paged_case": _bench_paged_case(verbose=verbose),
           "drift_case": _bench_drift_case("granite-8b", verbose=verbose)}
    if mesh_arg:
        from repro.launch.mesh import make_mesh
        from repro.launch.serve import parse_named_mesh
        shape, axes = parse_named_mesh(mesh_arg)
        mesh = make_mesh(shape, axes)
        out["sharded_cases"] = [
            _bench_sharded_case("granite-8b", True, mesh, mesh_arg, verbose),
            _bench_sharded_case("xlstm-350m", False, mesh, mesh_arg,
                                verbose),
        ]
    return out


def checks(results=None) -> list[Check]:
    results = results or run(verbose=False)
    cases = results["cases"]
    min_ratio = min(c["tok_s_ratio"] for c in cases)
    out = [
        Check("continuous batching beats static tok/s on every "
              "staggered trace",
              1.0 if min_ratio > 1.0 else 0.0, 1.0, rtol=0.01),
        Check("engine shapes jit-stable over ragged traces (no recompile)",
              1.0 if all(c["stable_shapes"] for c in cases) else 0.0,
              1.0, rtol=0.01),
        Check("synchronized arrivals bit-equal to the static path",
              1.0 if all(c["sync_bit_equal"] for c in cases) else 0.0,
              1.0, rtol=0.01),
        Check("per-request CM_* ledgers reconcile with AimcProgram",
              1.0 if all(c["ledger_exact"] for c in cases) else 0.0,
              1.0, rtol=0.01),
    ]
    paged = results.get("paged_case")
    if paged:
        out += [
            Check("paged engine bit-equal to dense on the shared-prompt "
                  "trace (prefix on and off)",
                  1.0 if paged["sync_bit_equal"] else 0.0, 1.0, rtol=0.01),
            Check("prefix cache beats prefix-off tok/s on the shared-"
                  f"system-prompt trace (>= {PREFIX_GAIN_MIN}x)",
                  1.0 if paged["prefix_tok_s_gain"] >= PREFIX_GAIN_MIN
                  else 0.0, 1.0, rtol=0.01),
            Check("shared system-prompt span prefilled exactly once "
                  "(every later request pays only its suffix)",
                  1.0 if paged["exactly_once"] else 0.0, 1.0, rtol=0.01),
            Check("chunked prefill cuts prompt-pad waste vs the dense "
                  "full-pad prefill",
                  1.0 if paged["pad_waste_cut"] else 0.0, 1.0, rtol=0.01),
            Check("paged arms: CM_* + page ledgers reconcile, shapes "
                  "jit-stable",
                  1.0 if paged["ledger_exact"] and paged["stable_shapes"]
                  else 0.0, 1.0, rtol=0.01),
        ]
    drift_case = results.get("drift_case")
    if drift_case:
        ch = drift_case["chaos"]
        out += [
            Check("conductance drift degrades probe accuracy past the "
                  "health threshold with program age",
                  1.0 if drift_case["drift_detected"] else 0.0, 1.0,
                  rtol=0.01),
            Check("hot recalibration recovers probe error to the fresh "
                  "tolerance (bit-exact reprogram)",
                  1.0 if drift_case["recal_recovers"] else 0.0, 1.0,
                  rtol=0.01),
            Check("mid-trace core kill: zero lost requests, books exact "
                  "incl. recal CM_INITIALIZE",
                  1.0 if ch["books_exact"] else 0.0, 1.0, rtol=0.01),
            Check("chaos run tokens bit-equal to the unfaulted run",
                  1.0 if ch["bit_equal"] else 0.0, 1.0, rtol=0.01),
        ]
    sharded = results.get("sharded_cases")
    if sharded:
        max_round_share = max(c["round_share_k_hi"] for c in sharded)
        # gate the raw sweep gain on the arch where the round dominates
        # the k=1 step (max across cases): a heavy-step arch's gain
        # ceiling is ~1.2x and sits inside noise — its recovery is gated
        # by the normalized round share instead
        best_chunk_gain = max(c["chunk_step_gain"] for c in sharded)
        max_resid = max(c["roofline"]["fit_residual_max"] for c in sharded)
        gain_explained = all(
            abs(c["roofline"]["predicted_gain"]
                - c["roofline"]["measured_gain"])
            <= ROOFLINE_RTOL * c["roofline"]["measured_gain"]
            for c in sharded)
        out += [
            Check("sharded engine bit-equal to single-device at every "
                  "chunk size",
                  1.0 if all(c["sync_bit_equal"] for c in sharded) else 0.0,
                  1.0, rtol=0.01),
            Check("sharded engine shapes jit-stable (no recompile)",
                  1.0 if all(c["stable_shapes"] for c in sharded) else 0.0,
                  1.0, rtol=0.01),
            Check("shard-aggregated per-core ledgers reconcile exactly",
                  1.0 if all(c["ledger_exact"] for c in sharded) else 0.0,
                  1.0, rtol=0.01),
            Check("chunked decode amortizes the per-token host round "
                  f"(k sweep step gain >= {CHUNK_GAIN_MIN}x where the "
                  "round dominates)",
                  1.0 if best_chunk_gain >= CHUNK_GAIN_MIN else 0.0, 1.0,
                  rtol=0.01),
            Check("host round reduced to a minor share of the k=max step "
                  f"(<= {ROUND_SHARE_MAX:.0%} per roofline)",
                  1.0 if max_round_share <= ROUND_SHARE_MAX else 0.0, 1.0,
                  rtol=0.01),
            Check("overlap roofline fit residual within gate",
                  1.0 if max_resid <= ROOFLINE_RTOL else 0.0, 1.0,
                  rtol=0.01),
            Check("roofline-predicted overlap gain matches measured",
                  1.0 if gain_explained else 0.0, 1.0, rtol=0.01),
        ]
    return out


if __name__ == "__main__":
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH",
                    help="write results + checks as JSON")
    ap.add_argument("--mesh", metavar="SPEC", default=None,
                    help="also bench the sharded engine on this mesh "
                         "(data:D,model:M); forces host-platform device "
                         "count as needed")
    args = ap.parse_args()
    if args.mesh:
        # must precede first backend use: XLA fixes the device count at init
        from repro.launch.serve import force_host_device_count
        force_host_device_count(args.mesh)
    res = run(mesh_arg=args.mesh)
    cs = checks(res)
    for c in cs:
        print(c.row())
    if args.json:
        payload = {"results": res,
                   "checks": [{"name": c.name, "measured": c.measured,
                               "target": c.target, "ok": c.ok} for c in cs]}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")
    sys.exit(0 if all(c.ok for c in cs) else 1)
